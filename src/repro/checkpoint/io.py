"""Crash-safe, sharding-aware checkpointing.

Single-process format: one ``.npz`` per save with ``/``-joined tree paths
as keys plus JSON manifests.  On a real multi-host pod each process saves
only the shards it owns (``addressable_shards``) into
``<dir>/proc<k>.npz`` — the same flat-key format — and restore reassembles
per-host; the container exercises the single-process path.

Crash safety (a preempted worker must NEVER leave the run unrestorable):

* every file is written **atomically** — tmp file, flush + fsync,
  ``os.replace`` — so a kill mid-write leaves at worst a stray ``.tmp``;
* each save writes the ``.npz`` first, then a per-step manifest
  (``ckpt_<step>.json``) carrying per-leaf CRC32 checksums, then updates
  the ``manifest.json`` latest-pointer **last**;
* :func:`restore_checkpoint` walks per-step manifests newest-first and
  returns the newest checkpoint that is *intact* (loads cleanly, has
  exactly the manifest's keys, checksums match) — a corrupt or truncated
  latest falls back to the previous one instead of crashing the resume;
* ``keep`` retains only the last K checkpoints (never the newest).

Deterministic kill/crash points for the fault harness
(``core/faults.py``, indexed by step): ``ckpt.data_tmp_written``,
``ckpt.data_replaced``, ``ckpt.manifest_step_written``.
"""
from __future__ import annotations

import glob
import io
import json
import os
import re
import zlib
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import faults as faults_mod

FORMAT_VERSION = 2


class CheckpointCorruptError(RuntimeError):
    """A checkpoint file is unreadable, truncated, or fails its checksum."""


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def _checksum(arr: np.ndarray) -> int:
    """CRC32 over raw bytes + dtype/shape (catches silent reinterpretation)."""
    meta = f"{arr.dtype.str}{arr.shape}".encode()
    return zlib.crc32(np.ascontiguousarray(arr).tobytes(), zlib.crc32(meta))


def _atomic_write(path: str, data: bytes, *, crash_site: Optional[str] = None,
                  crash_index: int = 0) -> None:
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    if crash_site is not None:
        faults_mod.crash_point(crash_site, crash_index)
    os.replace(tmp, path)


def _npz_path(direc: str, step: int) -> str:
    return os.path.join(direc, f"ckpt_{step:08d}.npz")


def _manifest_path(direc: str, step: int) -> str:
    return os.path.join(direc, f"ckpt_{step:08d}.json")


def save_checkpoint(direc: str, state, step: int,
                    keep: Optional[int] = None) -> str:
    """Atomically save ``state``; returns the ``.npz`` path.

    ``keep`` prunes all but the newest K checkpoints (and stray ``.tmp``
    leftovers from killed saves)."""
    os.makedirs(direc, exist_ok=True)
    flat = {k: np.asarray(jax.device_get(v)) for k, v in _flatten(state).items()}
    path = _npz_path(direc, step)
    buf = io.BytesIO()
    np.savez(buf, **flat)
    # data first (atomic): a kill before the manifests leaves an orphan
    # .npz that restore simply never considers.
    _atomic_write(path, buf.getvalue(),
                  crash_site="ckpt.data_tmp_written", crash_index=step)
    faults_mod.crash_point("ckpt.data_replaced", step)
    manifest = {
        "format": FORMAT_VERSION,
        "step": step,
        "latest": os.path.basename(path),
        "keys": sorted(flat.keys()),
        "checksums": {k: _checksum(v) for k, v in flat.items()},
    }
    mdata = json.dumps(manifest, indent=1).encode()
    # per-step manifest (the restore candidates), then the latest-pointer
    _atomic_write(_manifest_path(direc, step), mdata)
    faults_mod.crash_point("ckpt.manifest_step_written", step)
    _atomic_write(os.path.join(direc, "manifest.json"), mdata)
    if keep is not None:
        _prune(direc, keep)
    return path


def _prune(direc: str, keep: int) -> None:
    for tmp in glob.glob(os.path.join(direc, "*.tmp")):
        try:
            os.remove(tmp)
        except OSError:
            pass
    for step, _ in list_checkpoints(direc)[max(keep, 1):]:
        for p in (_npz_path(direc, step), _manifest_path(direc, step)):
            try:
                os.remove(p)
            except OSError:
                pass


def list_checkpoints(direc: str) -> List[Tuple[int, Dict]]:
    """(step, manifest) candidates, newest first.  Per-step manifests are
    authoritative; a legacy dir with only ``manifest.json`` still lists
    its single entry.  Unparseable manifests are skipped (a torn manifest
    must not block restore of an older checkpoint)."""
    out: List[Tuple[int, Dict]] = []
    seen = set()
    for mp in glob.glob(os.path.join(direc, "ckpt_*.json")):
        m = re.fullmatch(r"ckpt_(\d+)\.json", os.path.basename(mp))
        if not m:
            continue
        try:
            with open(mp) as f:
                manifest = json.load(f)
        except (OSError, ValueError):
            continue
        out.append((int(m.group(1)), manifest))
        seen.add(int(m.group(1)))
    legacy = os.path.join(direc, "manifest.json")
    if os.path.exists(legacy):
        try:
            with open(legacy) as f:
                manifest = json.load(f)
            if manifest.get("step") not in seen:
                out.append((manifest["step"], manifest))
        except (OSError, ValueError, KeyError):
            pass
    return sorted(out, key=lambda t: t[0], reverse=True)


def latest_step(direc: str) -> Optional[int]:
    """Newest candidate step, or None when the dir holds no checkpoints
    (missing dir included) — the ``--resume`` probe."""
    if not os.path.isdir(direc):
        return None
    cands = list_checkpoints(direc)
    return cands[0][0] if cands else None


def _load_verified(direc: str, manifest: Dict) -> Dict[str, np.ndarray]:
    """Load the manifest's ``.npz`` and verify keys + checksums; any
    failure mode (missing/truncated/bit-rotted file, zip errors, checksum
    mismatch) raises :class:`CheckpointCorruptError`."""
    latest = manifest["latest"]
    path = latest if os.path.isabs(latest) else os.path.join(direc, latest)
    try:
        with np.load(path) as data:
            arrays = {k: data[k] for k in data.files}
    except Exception as e:  # zipfile.BadZipFile, OSError, EOFError, ValueError…
        raise CheckpointCorruptError(
            f"checkpoint {path!r} is unreadable ({type(e).__name__}: {e})")
    want = set(manifest.get("keys", arrays.keys()))
    if set(arrays) != want:
        raise CheckpointCorruptError(
            f"checkpoint {path!r} keys disagree with its manifest: "
            f"missing {sorted(want - set(arrays))[:5]}, "
            f"unexpected {sorted(set(arrays) - want)[:5]}")
    sums = manifest.get("checksums")
    if sums:
        bad = [k for k, a in arrays.items()
               if k in sums and _checksum(a) != sums[k]]
        if bad:
            raise CheckpointCorruptError(
                f"checkpoint {path!r} failed checksum verification for "
                f"{len(bad)} leaves (first: {sorted(bad)[:3]})")
    return arrays


def restore_checkpoint(direc: str, state_template, *, fallback: bool = True):
    """Restore into the structure of ``state_template`` → (state, step).

    Walks candidates newest-first; a corrupt/truncated checkpoint is
    skipped (with a warning) in favour of the newest *intact* one unless
    ``fallback=False``.  Raises :class:`CheckpointCorruptError` when no
    candidate survives, FileNotFoundError when the dir has none at all,
    and ValueError when an intact checkpoint's keys don't match the
    template (wrong model — missing and unexpected keys named separately).
    """
    candidates = list_checkpoints(direc)
    if not candidates:
        raise FileNotFoundError(f"no checkpoint manifests under {direc!r}")
    errors: List[str] = []
    for step, manifest in candidates:
        try:
            arrays = _load_verified(direc, manifest)
        except CheckpointCorruptError as e:
            errors.append(str(e))
            if not fallback:
                raise
            print(f"checkpoint: step {step} corrupt, falling back ({e})")
            continue
        flat_tpl = _flatten(state_template)
        missing = sorted(set(flat_tpl) - set(arrays))
        unexpected = sorted(set(arrays) - set(flat_tpl))
        if missing or unexpected:
            raise ValueError(
                f"checkpoint step {step} does not match the restore "
                f"template: missing keys {missing[:10]} "
                f"(+{max(len(missing) - 10, 0)} more), unexpected keys "
                f"{unexpected[:10]} (+{max(len(unexpected) - 10, 0)} more)")
        paths, treedef = jax.tree_util.tree_flatten_with_path(state_template)
        new_leaves = []
        for path, leaf in paths:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            new_leaves.append(jnp.asarray(arrays[key]).astype(leaf.dtype))
        return jax.tree_util.tree_unflatten(treedef, new_leaves), step
    raise CheckpointCorruptError(
        f"no intact checkpoint under {direc!r}; tried {len(candidates)} "
        f"candidate(s): " + "; ".join(errors))
