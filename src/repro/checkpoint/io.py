"""Sharding-aware checkpointing.

Single-process format: one ``.npz`` per save with ``/``-joined tree paths
as keys plus a tiny JSON manifest.  On a real multi-host pod each process
saves only the shards it owns (``addressable_shards``) into
``<dir>/proc<k>.npz`` — the same flat-key format — and restore reassembles
per-host; the container exercises the single-process path.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict

import numpy as np

import jax
import jax.numpy as jnp


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def save_checkpoint(direc: str, state, step: int) -> str:
    os.makedirs(direc, exist_ok=True)
    flat = {k: np.asarray(jax.device_get(v)) for k, v in _flatten(state).items()}
    path = os.path.join(direc, f"ckpt_{step:08d}.npz")
    np.savez(path, **flat)
    with open(os.path.join(direc, "manifest.json"), "w") as f:
        json.dump({"latest": path, "step": step,
                   "keys": sorted(flat.keys())}, f, indent=1)
    return path


def restore_checkpoint(direc: str, state_template):
    """Restore into the structure of ``state_template`` (keeps shardings
    if the template leaves carry them via jax.device_put afterwards)."""
    with open(os.path.join(direc, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(manifest["latest"])
    flat_tpl = _flatten(state_template)
    assert set(flat_tpl) == set(data.files), (
        sorted(set(flat_tpl) ^ set(data.files))[:10])
    leaves_by_key = {k: jnp.asarray(data[k]) for k in data.files}
    paths, treedef = jax.tree_util.tree_flatten_with_path(state_template)
    new_leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        new_leaves.append(leaves_by_key[key].astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves), manifest["step"]
