from repro.checkpoint.io import (CheckpointCorruptError, latest_step,
                                 list_checkpoints, restore_checkpoint,
                                 save_checkpoint)
