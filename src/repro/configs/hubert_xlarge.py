"""HuBERT X-Large — encoder-only audio transformer (wav2vec2 arch).
[arXiv:2106.07447]  48L, d_model=1280, 16H (kv=16, MHA), d_ff=5120,
vocab=504 (cluster targets).

Audio carve-out: the mel-spectrogram + conv feature extractor (and its
conv positional embedding) are STUBBED — input_specs() provides frame
embeddings (B, S, d_model).  Encoder-only → bidirectional attention, NO
decode step: decode_32k and long_500k skipped (DESIGN.md §skips).
No MoE (§Arch-applicability).
"""
from repro.core.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    d_ff=5120,
    vocab_size=504,
    block_pattern=("attn",),
    attention=AttentionConfig(num_heads=16, num_kv_heads=16, use_rope=False,
                              causal=False),
    encoder_only=True,
    frontend="audio",
    act="gelu",
    source="HuBERT [arXiv:2106.07447]",
)
