"""InternVL2-2B — InternViT vision encoder + InternLM2 language backbone.
[arXiv:2404.16821]  Backbone: 24L, d_model=2048, 16H (GQA kv=8),
d_ff=8192, vocab=92553.

VLM carve-out: the ViT + projector are STUBBED — input_specs() provides
the merged patch+text embedding stream (B, S, d_model); this config is
the language/decoder transformer that consumes it.  long_500k skipped
(full attention).  No MoE (§Arch-applicability).
"""
from repro.core.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    d_ff=8192,
    vocab_size=92553,
    block_pattern=("attn",),
    attention=AttentionConfig(num_heads=16, num_kv_heads=8,
                              rope_theta=1_000_000.0),
    frontend="vision",
    act="swiglu",
    source="InternVL2 [arXiv:2404.16821]",
)
