"""StarCoder2-3B — GQA + RoPE code model.
[arXiv:2402.19173]  30L, d_model=3072, 24H (GQA kv=2), d_ff=12288,
vocab=49152.

Classic 4×d MLP (gelu, non-gated).  Pure full attention → long_500k
skipped (DESIGN.md §skips).  No MoE (§Arch-applicability).
"""
from repro.core.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    num_layers=30,
    d_model=3072,
    d_ff=12288,
    vocab_size=49152,
    block_pattern=("attn",),
    attention=AttentionConfig(num_heads=24, num_kv_heads=2,
                              rope_theta=999_999.0),
    act="gelu",
    source="StarCoder2 [arXiv:2402.19173]",
)
