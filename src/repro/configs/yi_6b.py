"""Yi-6B — llama-architecture GQA dense model.
[arXiv:2403.04652]  32L, d_model=4096, 32H (GQA kv=4), d_ff=11008, vocab=64000.

Pure full attention → long_500k skipped (DESIGN.md §skips).  No MoE.
"""
from repro.core.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="yi-6b",
    family="dense",
    num_layers=32,
    d_model=4096,
    d_ff=11008,
    vocab_size=64000,
    block_pattern=("attn",),
    attention=AttentionConfig(num_heads=32, num_kv_heads=4, rope_theta=5_000_000.0),
    act="swiglu",
    source="Yi [arXiv:2403.04652]",
)
