"""Llama-4 Maverick 400B-A17B — interleaved dense/MoE, 128 experts top-1,
shared expert, early fusion.  [hf:meta-llama/Llama-4-Scout-17B-16E family]
48L, d_model=5120, 40H (GQA kv=8), expert d_ff=8192, vocab=202048.

PRIMARY target for the paper's technique: 128-expert switch-style (top-1)
routing — expert-parallel AllToAll dominates.  MoE every other layer
(interleave step 2) + one always-on shared expert per MoE layer.
long_500k skipped (full attention).
"""
from repro.core.config import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    d_ff=8192,
    vocab_size=202048,
    block_pattern=("dense", "moe"),     # interleave_moe_layer_step = 2
    attention=AttentionConfig(num_heads=40, num_kv_heads=8, qk_norm=True,
                              rope_theta=500_000.0),
    moe=MoEConfig(num_experts=128, top_k=1, gate="switch",
                  capacity_factor=1.25, d_ff_expert=8192,
                  num_shared_experts=1, dispatch="sort", a2a="auto",
                  overlap_chunks="auto", grouped_block_m="auto",
                  grouped_ep_bound_factor="auto"),
    act="swiglu",
    source="Llama 4 [hf:meta-llama/Llama-4-Scout-17B-16E]",
)
