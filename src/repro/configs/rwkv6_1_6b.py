"""RWKV-6 "Finch" 1.6B — attention-free, data-dependent decay.
[arXiv:2404.05892]  24L, d_model=2048, d_ff=7168, vocab=65536.

§Arch-applicability: no MoE layers → HetuMoE's routing/AllToAll technique
does not apply; uses the shared substrate (scan, sharding, launcher).
Sub-quadratic (recurrent state) → runs long_500k.
"""
from repro.core.config import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    d_ff=7168,
    vocab_size=65536,
    block_pattern=("rwkv",),
    rwkv=RWKVConfig(head_dim=64, chunk_size=128, decay_lora=64, mix_lora=32),
    act="relu",          # RWKV channel-mix uses squared-relu-family activation
    source="Finch: RWKV-6 [arXiv:2404.05892]",
)
