"""Architecture registry: ``--arch <id>`` → ModelConfig.

``get_config(id)`` returns the EXACT assigned configuration (used by the
dry-run only — ShapeDtypeStruct, no allocation).  ``smoke_config(id)``
returns the reduced same-family variant (≤2-ish layers — one pattern
period — d_model≤512, ≤4 experts) that the CPU smoke tests instantiate
and step.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core.config import ModelConfig

from repro.configs.rwkv6_1_6b import CONFIG as _rwkv6
from repro.configs.h2o_danube_3_4b import CONFIG as _danube
from repro.configs.yi_6b import CONFIG as _yi
from repro.configs.llama4_maverick_400b_a17b import CONFIG as _llama4
from repro.configs.dbrx_132b import CONFIG as _dbrx
from repro.configs.internvl2_2b import CONFIG as _internvl
from repro.configs.zamba2_7b import CONFIG as _zamba
from repro.configs.gemma2_9b import CONFIG as _gemma2
from repro.configs.hubert_xlarge import CONFIG as _hubert
from repro.configs.starcoder2_3b import CONFIG as _starcoder
from repro.configs.hetumoe_paper_16e import CONFIG as _paper

ARCHS: Dict[str, ModelConfig] = {c.name: c for c in (
    _rwkv6, _danube, _yi, _llama4, _dbrx, _internvl, _zamba, _gemma2,
    _hubert, _starcoder, _paper)}

ASSIGNED = [c.name for c in (_rwkv6, _danube, _yi, _llama4, _dbrx,
                             _internvl, _zamba, _gemma2, _hubert, _starcoder)]


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch]


def smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests."""
    cfg = get_config(arch)
    period = len(cfg.block_pattern)
    kw = dict(
        name=cfg.name + "-smoke",
        num_layers=period if period > 1 else 2,
        d_model=128,
        d_ff=256,
        vocab_size=512,
        local_window=32,
    )
    if cfg.attention is not None:
        kw["attention"] = dataclasses.replace(
            cfg.attention, num_heads=4,
            num_kv_heads=max(1, min(cfg.attention.num_kv_heads, 2)),
            head_dim=32, window=32 if cfg.attention.window else None)
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, d_ff_expert=256,
            num_prototypes=min(cfg.moe.num_prototypes, 2),
            num_groups=min(cfg.moe.num_groups, 2))
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, head_dim=16,
                                        chunk_size=8)
    if cfg.rwkv is not None:
        kw["rwkv"] = dataclasses.replace(cfg.rwkv, head_dim=16, chunk_size=8,
                                         decay_lora=8, mix_lora=4)
    return cfg.replace(**kw)
