"""Zamba2-7B — hybrid: Mamba-2 backbone + SHARED attention blocks.
[arXiv:2411.15242]  81L, d_model=3584, 32H (kv=32, MHA in the shared
block), d_ff=14336, vocab=32000, ssm_state=64.

Pattern: two Mamba-2 blocks then one Mamba-2 + shared-attention block
(one attention param set reused at every occurrence, LoRA-adapted per
occurrence — Zamba2's parameter-sharing trick).  Sub-quadratic: Mamba
state is O(1); the shared attention uses a bounded ring window in
long-context serving (documented variant) → runs long_500k.
No MoE (§Arch-applicability).
"""
from repro.core.config import AttentionConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    d_ff=14336,
    vocab_size=32000,
    block_pattern=("mamba", "mamba", "mamba_sa"),
    attention=AttentionConfig(num_heads=32, num_kv_heads=32,
                              rope_theta=10_000.0),
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, chunk_size=128,
                  conv_width=4, n_groups=1),
    local_window=4096,
    act="swiglu",
    source="Zamba2 [arXiv:2411.15242]",
)
