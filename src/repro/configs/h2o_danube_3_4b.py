"""H2O-Danube-3 4B — llama+mistral mix with sliding-window attention.
[arXiv:2401.16818]  24L, d_model=3840, 32H (GQA kv=8), d_ff=10240, vocab=32000.

Native SWA (window 4096) → sub-quadratic decode → runs long_500k with the
ring KV cache.  No MoE layers (§Arch-applicability).
"""
from repro.core.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    num_layers=24,
    d_model=3840,
    d_ff=10240,
    vocab_size=32000,
    block_pattern=("attn",),
    attention=AttentionConfig(num_heads=32, num_kv_heads=8, window=4096,
                              rope_theta=10_000.0),
    act="swiglu",
    source="H2O-Danube3 [arXiv:2401.16818]",
)
