"""DBRX 132B — fine-grained MoE, 16 experts top-4 every layer.
[hf:databricks/dbrx-base]  40L, d_model=6144, 48H (GQA kv=8),
expert d_ff=10752, vocab=100352.

PRIMARY target for the paper's technique: exercises the k=4 top-k gating
path + fine-grained expert parallelism (1 expert per model-rank on the
16-wide model axis).  long_500k skipped (full attention).
"""
from repro.core.config import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    d_ff=10752,
    vocab_size=100352,
    block_pattern=("moe",),
    attention=AttentionConfig(num_heads=48, num_kv_heads=8,
                              rope_theta=500_000.0),
    moe=MoEConfig(num_experts=16, top_k=4, gate="topk",
                  capacity_factor=1.25, d_ff_expert=10752,
                  dispatch="sort", a2a="auto", overlap_chunks="auto",
                  grouped_block_m="auto", grouped_ep_bound_factor="auto"),
    act="swiglu",
    source="DBRX [hf:databricks/dbrx-base]",
)
