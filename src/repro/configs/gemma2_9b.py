"""Gemma-2 9B — alternating local/global attention, logit softcaps.
[arXiv:2408.00118]  42L, d_model=3584, 16H (GQA kv=8, head_dim=256),
d_ff=14336, vocab=256000.

Local layers: sliding window 4096; global layers: full attention with
attn-logit softcap 50 and final-logit softcap 30; GeGLU; tied + scaled
embeddings.  long_500k runs as the documented variant with global layers
capped to the local window.  No MoE (§Arch-applicability).
"""
from repro.core.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    d_ff=14336,
    vocab_size=256000,
    block_pattern=("local", "global"),
    attention=AttentionConfig(num_heads=16, num_kv_heads=8, head_dim=256,
                              rope_theta=10_000.0, attn_softcap=50.0),
    local_window=4096,
    final_softcap=30.0,
    act="geglu",
    tie_embeddings=True,
    scale_embeddings=True,
    source="Gemma 2 [arXiv:2408.00118]",
)
