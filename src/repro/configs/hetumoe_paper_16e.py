"""The paper's own benchmark model (§3.2 "Overall Performance"):
a 16-expert MoE layer, expert FFN hidden 2048, embedding dim 2048,
sequence length 1024 — used by benchmarks/ to reproduce Figs. 1, 7, 8.

Modeled as a 2-layer MoE transformer so the same launcher/dry-run
machinery applies; the benchmarks also drive the bare MoE layer directly
(PAPER_LAYER dims below).
"""
from repro.core.config import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="hetumoe-paper-16e",
    family="moe",
    num_layers=2,
    d_model=2048,
    d_ff=2048,
    vocab_size=50304,
    block_pattern=("moe",),
    attention=AttentionConfig(num_heads=16, num_kv_heads=16),
    moe=MoEConfig(num_experts=16, top_k=1, gate="switch",
                  capacity_factor=1.25, d_ff_expert=2048,
                  dispatch="sort", a2a="auto", overlap_chunks="auto",
                  grouped_block_m="auto", grouped_ep_bound_factor="auto"),
    act="relu",
    source="HetuMoE paper §3.2 (16e, d_ff=2048, seq=1024, d=2048)",
)

# Raw dims for the layer-level benchmarks (Figs. 1/7/8)
PAPER_LAYER = dict(d_model=2048, d_ff=2048, num_experts=16, seq_len=1024)
