"""Dense-to-Sparse gate annealing (paper §3.1, Nie et al. 2021).

The dense_to_sparse gate routes via Gumbel-softmax at temperature T;
training starts dense (high T — every slot weighted nearly equally,
approximating routing to all experts) and anneals toward sparse
(T → T_min — mass collapses onto the top-1 slot).  The schedule is a
host-side exponential decay applied by swapping the (frozen-dataclass)
MoEConfig per step — configs are static jit constants, so this costs one
retrace per DISTINCT temperature; use ``levels`` to quantize the
schedule into a handful of compilation buckets.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.config import ModelConfig, MoEConfig


def d2s_temperature(step: int, *, t_start: float = 2.0, t_min: float = 0.05,
                    decay_steps: int = 1000, levels: int = 8) -> float:
    """Exponentially annealed, quantized to ``levels`` buckets."""
    frac = min(step / max(decay_steps, 1), 1.0)
    t = t_start * (t_min / t_start) ** frac
    # quantize in log space to bound retraces
    lo, hi = math.log(t_min), math.log(t_start)
    q = round((math.log(t) - lo) / (hi - lo) * (levels - 1)) / (levels - 1)
    return float(math.exp(lo + q * (hi - lo)))


def with_temperature(cfg: ModelConfig, t: float) -> ModelConfig:
    assert cfg.moe is not None and cfg.moe.gate == "dense_to_sparse", cfg.name
    return cfg.replace(moe=dataclasses.replace(cfg.moe, gumbel_temperature=t))
