"""Train step: chunked CE loss, gradient accumulation, clipping, AdamW,
and the non-finite skip-step guard.

Chunked cross-entropy: the unembed + softmax-CE is scanned over sequence
chunks so the full (B, S, V) logits tensor is NEVER materialized — at
gemma2's V=256k that tensor is ~2 GB/device f32 on train_4k; chunking
caps it at (B, S/nc, V).  This is a beyond-paper memory optimization
recorded in EXPERIMENTS.md §Perf.

Gradient accumulation: ``lax.scan`` over microbatches (the standard
jax idiom — one compiled step regardless of accumulation factor).

Skip-step guard (fault tolerance): one NaN/Inf gradient must not corrupt
the optimizer state — the step's update is suppressed with ``jnp.where``
(params, moments, AND the Adam bias-correction count stay bitwise
unchanged) and ``TrainState`` carries ``skipped`` / ``nonfinite_streak``
counters so the driver can fail fast after ``tcfg.max_skipped_steps``
consecutive bad steps.  ``tcfg.loss_scale`` adds (static or dynamic)
loss scaling for bf16: the loss is scaled before the backward, grads are
unscaled before clipping, and in "dynamic" mode the scale halves on a
bad step and doubles after ``loss_scale_growth_interval`` good ones.
Injection seams for the fault harness (``core/faults.py``):
``train.activations``, ``train.loss``, ``train.grads``.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import faults as faults_mod
from repro.core.config import ModelConfig, TrainConfig
from repro.models import transformer as T
from repro.optim import adamw_update, clip_by_global_norm, init_opt_state, make_schedule

# dynamic loss scaling bounds (standard mixed-precision choices)
_DYNAMIC_SCALE_INIT = 2.0 ** 15
_SCALE_MIN = 1.0
_SCALE_MAX = 2.0 ** 24


class TrainState(NamedTuple):
    params: Any
    opt: Dict
    step: jax.Array
    # fault-tolerance counters (None only in legacy 3-field construction;
    # init_train_state always fills real scalars)
    skipped: Any = None            # i32: total skipped (non-finite) steps
    nonfinite_streak: Any = None   # i32: CONSECUTIVE skipped steps
    good_streak: Any = None        # i32: consecutive finite steps (scale growth)
    loss_scale: Any = None         # f32: current loss scale


def init_loss_scale(tcfg: TrainConfig) -> float:
    return (_DYNAMIC_SCALE_INIT if tcfg.loss_scale == "dynamic"
            else float(tcfg.loss_scale))


def init_train_state(rng: jax.Array, cfg: ModelConfig,
                     tcfg: TrainConfig) -> TrainState:
    params = T.init_model(rng, cfg)
    # distinct zero buffers: donated state must not alias across leaves
    zero = lambda: jnp.zeros((), jnp.int32)
    return TrainState(params, init_opt_state(params, tcfg), zero(),
                      skipped=zero(), nonfinite_streak=zero(),
                      good_streak=zero(),
                      loss_scale=jnp.float32(init_loss_scale(tcfg)))


def _auto_chunks(S: int, V: int) -> int:
    """Pick the CE chunk count so one chunk's logits stay ~2^25 elements
    per batch row (≈ 128 MB/device at B_local≈16, f32) — the memory knob
    that keeps gemma2 (V=256k) and internvl2 (V=92k) under HBM."""
    target_tokens = max(16, 2 ** 25 // max(V, 1))
    nc = 1
    while S % (nc * 2) == 0 and S // nc > target_tokens and nc < 64:
        nc *= 2
    return nc


def chunked_ce_loss(params, cfg: ModelConfig, h: jax.Array, targets: jax.Array,
                    mask: jax.Array, mesh=None, num_chunks: Optional[int] = None):
    """Scan the unembed+CE over sequence chunks.  h (B,S,d) → scalar."""
    B, S, d = h.shape
    nc = num_chunks or _auto_chunks(S, cfg.vocab_size)
    while S % nc:
        nc -= 1
    hc = h.reshape(B, nc, S // nc, d).transpose(1, 0, 2, 3)
    tc = targets.reshape(B, nc, S // nc).transpose(1, 0, 2)
    mc = mask.reshape(B, nc, S // nc).transpose(1, 0, 2)

    # remat the chunk body: without it, scan's VJP stacks every chunk's
    # exp(logits) residual — i.e. the full (S, V) f32 tensor the chunking
    # was supposed to avoid (22.6 GiB/dev for internvl2 train_4k).
    @jax.checkpoint
    def body(acc, xs):
        hi, ti, mi = xs
        logits = T.logits_from_hidden(params, cfg, hi, mesh).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ti[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mi
        return (acc[0] + jnp.sum(nll), acc[1] + jnp.sum(mi)), None

    (tot, cnt), _ = lax.scan(body, (jnp.zeros((), jnp.float32),) * 2,
                             (hc, tc, mc))
    return tot / jnp.maximum(cnt, 1.0)


def donation_alias_pairs(tree) -> list:
    """Leaf paths in ``tree`` (a donated pytree, e.g. a ``TrainState``)
    that share one buffer.

    The driver donates the whole train state to the compiled step; two
    leaves backed by the SAME array make XLA's donation reject the alias
    (or silently un-donate, doubling the state's HBM residency).  This is
    why ``init_train_state`` builds DISTINCT zero scalars for the
    counters — the contract the ``donation-alias`` lint rule
    (``repro.analysis``) enforces.  Returns ``[(path_a, path_b), ...]``
    for every aliased pair (empty = safe to donate).
    """
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]

    def key(leaf):
        try:  # committed single-device arrays: compare the real buffer
            return ("ptr", leaf.unsafe_buffer_pointer())
        except Exception:  # tracers / sharded arrays: object identity
            return ("id", id(leaf))

    seen: Dict[Any, str] = {}
    pairs = []
    for path, leaf in leaves:
        name = jax.tree_util.keystr(path)
        k = key(leaf)
        if k in seen:
            pairs.append((seen[k], name))
        else:
            seen[k] = name
    return pairs


def _tree_where(ok, new, old):
    """Per-leaf select: ``new`` on a finite step, ``old`` (bitwise) on a
    skipped one.  ``jnp.where(False, nan, x)`` returns ``x`` unchanged."""
    return jax.tree.map(lambda n, o: jnp.where(ok, n, o), new, old)


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, mesh=None,
                    faults: Optional[faults_mod.FaultPlan] = None):
    """Returns train_step(state, batch, rng) → (state, metrics).

    ``batch`` holds the GLOBAL batch; with ``tcfg.microbatches > 1`` it is
    split on the batch axis and accumulated via scan.

    ``faults`` (a ``core.faults.FaultPlan``) arms the traced injection
    seams at trace time; None (production) inserts no extra ops.
    """
    sched = make_schedule(tcfg)
    dynamic = tcfg.loss_scale == "dynamic"
    static_scale = not dynamic and float(tcfg.loss_scale) == 1.0

    def train_step(state: TrainState, batch, rng) -> Tuple[TrainState, Dict]:
        mbs = tcfg.microbatches
        scale = (jnp.float32(1.0) if static_scale
                 else state.loss_scale.astype(jnp.float32))

        def loss_fn(params, mb, r):
            h, aux, _ = T.forward(params, mb["inputs"], cfg, mesh=mesh, rng=r,
                                  remat=tcfg.remat)
            h = faults_mod.apply_traced(faults, "train.activations",
                                        state.step, h)
            ce = chunked_ce_loss(params, cfg, h, mb["targets"],
                                 mb["loss_mask"], mesh)
            loss = ce + aux
            loss = faults_mod.apply_traced(faults, "train.loss",
                                           state.step, loss)
            scaled = loss if static_scale else loss * scale
            return scaled, (loss, ce, aux)

        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

        if mbs == 1:
            (_, (loss, ce, aux)), grads = grad_fn(state.params, batch, rng)
        else:
            def split(x):
                return x.reshape(mbs, x.shape[0] // mbs, *x.shape[1:])
            mb_batch = jax.tree.map(split, batch)
            rngs = jax.random.split(rng, mbs)

            def body(acc, xs):
                mb, r = xs
                (_, (l, c, a)), g = grad_fn(state.params, mb, r)
                gacc, lacc, cacc, aacc = acc
                gacc = jax.tree.map(jnp.add, gacc, g)
                return (gacc, lacc + l, cacc + c, aacc + a), None

            zeros = jax.tree.map(jnp.zeros_like, state.params)
            (grads, loss, ce, aux), _ = lax.scan(
                body, (zeros, jnp.zeros(()), jnp.zeros(()), jnp.zeros(())),
                (mb_batch, rngs))
            grads = jax.tree.map(lambda g: g / mbs, grads)
            loss, ce, aux = loss / mbs, ce / mbs, aux / mbs

        grads = faults_mod.apply_traced(faults, "train.grads", state.step,
                                        grads)

        # -- non-finite guard ---------------------------------------------
        # Under single-controller jit these arrays are global, so reducing
        # them IS the cross-device all-reduce of the isfinite check (XLA
        # inserts the collective for sharded leaves).
        ok = jnp.isfinite(loss)
        for g in jax.tree.leaves(grads):
            ok = ok & jnp.all(jnp.isfinite(g))

        if not static_scale:
            # unscale AFTER the finite check (an overflowed Inf grad must
            # be seen as non-finite, not Inf/scale); skipped steps never
            # consume the unscaled values.
            inv = (jnp.float32(1.0) / scale)
            grads = jax.tree.map(lambda g: (g * inv.astype(g.dtype)), grads)

        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        lr = sched(state.step)
        new_params, new_opt = adamw_update(grads, state.opt, state.params,
                                           tcfg, lr)
        # bad step: params, moments AND the bias-correction count keep
        # their old bits — the update never happened.
        new_params = _tree_where(ok, new_params, state.params)
        new_opt = _tree_where(ok, new_opt, state.opt)

        oki = ok.astype(jnp.int32)
        skipped = state.skipped + (1 - oki)
        streak = jnp.where(ok, 0, state.nonfinite_streak + 1)
        good = jnp.where(ok, state.good_streak + 1, 0)
        if dynamic:
            grow = ok & (good >= tcfg.loss_scale_growth_interval)
            new_scale = jnp.where(
                ok,
                jnp.where(grow, jnp.minimum(scale * 2.0, _SCALE_MAX), scale),
                jnp.maximum(scale * 0.5, _SCALE_MIN))
            good = jnp.where(grow, 0, good)
        else:
            new_scale = state.loss_scale

        metrics = {"loss": loss, "ce": ce, "aux": aux,
                   "grad_norm": gnorm, "lr": lr,
                   "skipped": skipped, "nonfinite_streak": streak,
                   "loss_scale": new_scale}
        return TrainState(new_params, new_opt, state.step + 1,
                          skipped=skipped, nonfinite_streak=streak,
                          good_streak=good, loss_scale=new_scale), metrics

    return train_step
