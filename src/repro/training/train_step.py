"""Train step: chunked CE loss, gradient accumulation, clipping, AdamW.

Chunked cross-entropy: the unembed + softmax-CE is scanned over sequence
chunks so the full (B, S, V) logits tensor is NEVER materialized — at
gemma2's V=256k that tensor is ~2 GB/device f32 on train_4k; chunking
caps it at (B, S/nc, V).  This is a beyond-paper memory optimization
recorded in EXPERIMENTS.md §Perf.

Gradient accumulation: ``lax.scan`` over microbatches (the standard
jax idiom — one compiled step regardless of accumulation factor).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.config import ModelConfig, TrainConfig
from repro.models import transformer as T
from repro.optim import adamw_update, clip_by_global_norm, init_opt_state, make_schedule


class TrainState(NamedTuple):
    params: Any
    opt: Dict
    step: jax.Array


def init_train_state(rng: jax.Array, cfg: ModelConfig,
                     tcfg: TrainConfig) -> TrainState:
    params = T.init_model(rng, cfg)
    return TrainState(params, init_opt_state(params, tcfg),
                      jnp.zeros((), jnp.int32))


def _auto_chunks(S: int, V: int) -> int:
    """Pick the CE chunk count so one chunk's logits stay ~2^25 elements
    per batch row (≈ 128 MB/device at B_local≈16, f32) — the memory knob
    that keeps gemma2 (V=256k) and internvl2 (V=92k) under HBM."""
    target_tokens = max(16, 2 ** 25 // max(V, 1))
    nc = 1
    while S % (nc * 2) == 0 and S // nc > target_tokens and nc < 64:
        nc *= 2
    return nc


def chunked_ce_loss(params, cfg: ModelConfig, h: jax.Array, targets: jax.Array,
                    mask: jax.Array, mesh=None, num_chunks: Optional[int] = None):
    """Scan the unembed+CE over sequence chunks.  h (B,S,d) → scalar."""
    B, S, d = h.shape
    nc = num_chunks or _auto_chunks(S, cfg.vocab_size)
    while S % nc:
        nc -= 1
    hc = h.reshape(B, nc, S // nc, d).transpose(1, 0, 2, 3)
    tc = targets.reshape(B, nc, S // nc).transpose(1, 0, 2)
    mc = mask.reshape(B, nc, S // nc).transpose(1, 0, 2)

    # remat the chunk body: without it, scan's VJP stacks every chunk's
    # exp(logits) residual — i.e. the full (S, V) f32 tensor the chunking
    # was supposed to avoid (22.6 GiB/dev for internvl2 train_4k).
    @jax.checkpoint
    def body(acc, xs):
        hi, ti, mi = xs
        logits = T.logits_from_hidden(params, cfg, hi, mesh).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ti[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mi
        return (acc[0] + jnp.sum(nll), acc[1] + jnp.sum(mi)), None

    (tot, cnt), _ = lax.scan(body, (jnp.zeros((), jnp.float32),) * 2,
                             (hc, tc, mc))
    return tot / jnp.maximum(cnt, 1.0)


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, mesh=None):
    """Returns train_step(state, batch, rng) → (state, metrics).

    ``batch`` holds the GLOBAL batch; with ``tcfg.microbatches > 1`` it is
    split on the batch axis and accumulated via scan.
    """
    sched = make_schedule(tcfg)

    def loss_fn(params, mb, rng):
        h, aux, _ = T.forward(params, mb["inputs"], cfg, mesh=mesh, rng=rng,
                              remat=tcfg.remat)
        ce = chunked_ce_loss(params, cfg, h, mb["targets"], mb["loss_mask"],
                             mesh)
        return ce + aux, (ce, aux)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch, rng) -> Tuple[TrainState, Dict]:
        mbs = tcfg.microbatches

        if mbs == 1:
            (loss, (ce, aux)), grads = grad_fn(state.params, batch, rng)
        else:
            def split(x):
                return x.reshape(mbs, x.shape[0] // mbs, *x.shape[1:])
            mb_batch = jax.tree.map(split, batch)
            rngs = jax.random.split(rng, mbs)

            def body(acc, xs):
                mb, r = xs
                (l, (c, a)), g = grad_fn(state.params, mb, r)
                gacc, lacc, cacc, aacc = acc
                gacc = jax.tree.map(jnp.add, gacc, g)
                return (gacc, lacc + l, cacc + c, aacc + a), None

            zeros = jax.tree.map(jnp.zeros_like, state.params)
            (grads, loss, ce, aux), _ = lax.scan(
                body, (zeros, jnp.zeros(()), jnp.zeros(()), jnp.zeros(())),
                (mb_batch, rngs))
            grads = jax.tree.map(lambda g: g / mbs, grads)
            loss, ce, aux = loss / mbs, ce / mbs, aux / mbs

        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        lr = sched(state.step)
        new_params, new_opt = adamw_update(grads, state.opt, state.params,
                                           tcfg, lr)
        metrics = {"loss": loss, "ce": ce, "aux": aux,
                   "grad_norm": gnorm, "lr": lr}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step
