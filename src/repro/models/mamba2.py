"""Mamba-2 (SSD) block — chunked scan for train/prefill, O(1) decode.

State-space duality form (Dao & Gu 2024), scalar decay per head:

    S_t = a_t · S_{t-1} + Δ_t · (x_t ⊗ B_t)       S ∈ R^{hd×N}
    y_t = S_t C_t + D ⊙ x_t,   a_t = exp(-exp(A_log)·Δ_t)

Because the decay is scalar per head the chunked pairwise matrix
``exp(cum_t − cum_s)`` is formed directly (always ≤ 1 — no clipping
needed, unlike RWKV-6's per-channel decay).  Intra-chunk work is two
(L×L) matmuls per head on the MXU; inter-chunk state is a ``lax.scan``.

Used by zamba2 (hybrid Mamba2 + shared-attention architecture).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.config import SSMConfig
from repro.models.layers import rms_norm


def _dims(cfg: SSMConfig, d: int):
    d_in = cfg.expand * d
    H = d_in // cfg.head_dim
    return d_in, H, cfg.n_groups, cfg.d_state


def init_mamba_block(rng: jax.Array, cfg: SSMConfig, d: int) -> Dict[str, jax.Array]:
    d_in, H, G, N = _dims(cfg, d)
    ks = jax.random.split(rng, 6)
    s = d ** -0.5
    conv_ch = d_in + 2 * G * N
    return {
        # fused in_proj → [z, x, B, C, dt]
        "w_in": jax.random.normal(ks[0], (d, 2 * d_in + 2 * G * N + H),
                                  jnp.float32) * s,
        "conv_w": jax.random.normal(ks[1], (cfg.conv_width, conv_ch),
                                    jnp.float32) * cfg.conv_width ** -0.5,
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[2], (H,), jnp.float32,
                                       jnp.log(1e-3), jnp.log(1e-1))))),
        "norm": jnp.zeros((d_in,), jnp.float32),
        "w_out": jax.random.normal(ks[3], (d_in, d), jnp.float32) * d_in ** -0.5,
    }


def _split_proj(p, u, cfg: SSMConfig, d: int):
    d_in, H, G, N = _dims(cfg, d)
    h = u @ p["w_in"].astype(u.dtype)
    z = h[..., :d_in]
    xBC = h[..., d_in:2 * d_in + 2 * G * N]
    dt = h[..., 2 * d_in + 2 * G * N:]
    return z, xBC, dt


def _causal_conv(xBC, w, b, *, state=None):
    """Depthwise causal conv, width K.  xBC (B,S,C); state (B,K-1,C) holds
    the previous K-1 inputs (decode carry).  Returns (out, new_state)."""
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((xBC.shape[0], K - 1, xBC.shape[-1]), xBC.dtype)
    full = jnp.concatenate([state.astype(xBC.dtype), xBC], axis=1)
    out = sum(full[:, i:i + xBC.shape[1]] * w[i].astype(xBC.dtype)
              for i in range(K))
    out = jax.nn.silu(out + b.astype(xBC.dtype))
    return out, full[:, -(K - 1):]


def _ssd_chunk(Cc, Bc, Xc, cum, dtc, state):
    """One chunk.  Cc/Bc (B,L,H,N) f32, Xc (B,L,H,hd), cum/dtc (B,L,H),
    state (B,H,hd,N)."""
    decay_out = jnp.exp(cum)                                   # (B,L,H)
    # inter-chunk: y_t += exp(cum_t) · C_t S0
    y = jnp.einsum("blhn,bhpn,blh->blhp", Cc, state, decay_out)
    # intra-chunk: pairwise scalar decays (≤1), lower-tri inclusive
    pair = jnp.exp(cum[:, :, None] - cum[:, None, :])          # (B,L,L,H)
    L = Cc.shape[1]
    mask = jnp.tril(jnp.ones((L, L), bool))
    pair = jnp.where(mask[None, :, :, None], pair, 0.0)
    scores = jnp.einsum("blhn,bmhn,blmh,bmh->bhlm", Cc, Bc, pair, dtc)
    y = y + jnp.einsum("bhlm,bmhp->blhp", scores, Xc)
    # carry: S' = exp(cum_L) S0 + Σ_s exp(cum_L - cum_s) Δ_s (x_s ⊗ B_s)
    wlast = jnp.exp(cum[:, -1:] - cum) * dtc                   # (B,L,H)
    state = jnp.exp(cum[:, -1])[..., None, None] * state + \
        jnp.einsum("blh,blhp,blhn->bhpn", wlast, Xc, Bc)
    return y, state


def _head_constraint(mesh):
    """§Perf (zamba2 train hillclimb): Mamba blocks are head-parallel —
    every op between in_proj and out_proj is independent per head — but
    the chunked-scan reshapes defeat XLA's sharding propagation and it
    falls back to all-gathering the full (B,S,14k) activations per block
    (1.6 TB/dev/step).  Pinning the head axis to `model` keeps the whole
    SSD pipeline TP with a single out-proj all-reduce, like attention."""
    import os
    if mesh is None or mesh.devices.size == 1 \
            or os.environ.get("REPRO_MAMBA_TP", "1") != "1":
        return lambda a, axis: a
    from jax.sharding import NamedSharding, PartitionSpec
    dp = tuple(x for x in mesh.axis_names if x in ("pod", "data"))
    msize = mesh.shape.get("model", 1)

    def constrain(a, axis):
        if a.shape[axis] % msize:
            return a
        dims = [None] * a.ndim
        dims[0] = dp
        dims[axis] = "model"
        return jax.lax.with_sharding_constraint(
            a, NamedSharding(mesh, PartitionSpec(*dims)))
    return constrain


def mamba_forward(p: Dict[str, jax.Array], u: jax.Array, cfg: SSMConfig, d: int,
                  mesh=None) -> Tuple[jax.Array, dict]:
    """Full-sequence pass.  u (B,S,d) → (y (B,S,d), final ssm+conv state)."""
    B, S, _ = u.shape
    d_in, H, G, N = _dims(cfg, d)
    hd = cfg.head_dim
    cons = _head_constraint(mesh)
    z, xBC, dt = _split_proj(p, u, cfg, d)
    z = cons(z, 2)
    xBC = cons(xBC, 2)
    xBC, conv_state = _causal_conv(xBC, p["conv_w"], p["conv_b"])
    xBC = cons(xBC, 2)
    x = xBC[..., :d_in].reshape(B, S, H, hd).astype(jnp.float32)
    Bm = xBC[..., d_in:d_in + G * N].reshape(B, S, G, N).astype(jnp.float32)
    Cm = xBC[..., d_in + G * N:].reshape(B, S, G, N).astype(jnp.float32)
    rep = H // G
    x = cons(x, 2)
    Bh = cons(jnp.repeat(Bm, rep, axis=2), 2)                   # (B,S,H,N)
    Ch = cons(jnp.repeat(Cm, rep, axis=2), 2)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    dt = cons(dt, 2)
    loga = -jnp.exp(p["A_log"])[None, None] * dt                 # log a_t ≤ 0
    L = min(cfg.chunk_size, S)
    while S % L:                 # largest divisor of S ≤ chunk_size
        L -= 1
    nc = S // L

    def chunks(a):
        return a.reshape(B, nc, L, *a.shape[2:]).transpose(1, 0, 2, *range(3, a.ndim + 1))

    state0 = jnp.zeros((B, H, hd, N), jnp.float32)

    # checkpoint: otherwise scan's VJP stacks every chunk's (L,L,H)
    # pairwise-decay residuals across all chunks (3.5 GiB/dev each at
    # zamba2 train_4k scale); recompute them in backward instead
    @jax.checkpoint
    def body(state, inp):
        Cc, Bc, Xc, lac, dtc = inp
        cum = jnp.cumsum(lac, axis=1)
        y, state = _ssd_chunk(Cc, Bc, Xc, cum, dtc, state)
        return state, y

    state, ys = lax.scan(body, state0, (chunks(Ch), chunks(Bh), chunks(x),
                                        chunks(loga), chunks(dt)))
    y = cons(ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd), 2)
    y = y + p["D"][None, None, :, None] * x
    y = y.reshape(B, S, d_in).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    return y @ p["w_out"].astype(u.dtype), \
        {"s": state, "conv": conv_state, "pos": jnp.asarray(S, jnp.int32)}


def init_mamba_state(cfg: SSMConfig, batch: int, d: int):
    d_in, H, G, N = _dims(cfg, d)
    return {"s": jnp.zeros((batch, H, cfg.head_dim, N), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, d_in + 2 * G * N),
                              jnp.float32),
            "pos": jnp.zeros((), jnp.int32)}


def mamba_decode_step(p: Dict[str, jax.Array], u: jax.Array, state, cfg: SSMConfig,
                      d: int) -> Tuple[jax.Array, dict]:
    """One token.  u (B,1,d)."""
    B = u.shape[0]
    d_in, H, G, N = _dims(cfg, d)
    hd = cfg.head_dim
    z, xBC, dt = _split_proj(p, u, cfg, d)
    xBC, conv_state = _causal_conv(xBC, p["conv_w"], p["conv_b"],
                                   state=state["conv"])
    x = xBC[:, 0, :d_in].reshape(B, H, hd).astype(jnp.float32)
    Bm = xBC[:, 0, d_in:d_in + G * N].reshape(B, G, N).astype(jnp.float32)
    Cm = xBC[:, 0, d_in + G * N:].reshape(B, G, N).astype(jnp.float32)
    rep = H // G
    Bh, Ch = jnp.repeat(Bm, rep, axis=1), jnp.repeat(Cm, rep, axis=1)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = jnp.exp(-jnp.exp(p["A_log"])[None] * dt)
    S0 = state["s"]
    s_new = a[..., None, None] * S0 + \
        jnp.einsum("bh,bhp,bhn->bhpn", dt, x, Bh)
    y = jnp.einsum("bhpn,bhn->bhp", s_new, Ch) + p["D"][None, :, None] * x
    y = y.reshape(B, 1, d_in).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    return y @ p["w_out"].astype(u.dtype), \
        {"s": s_new, "conv": conv_state, "pos": state["pos"] + 1}
