"""Model zoo substrate: layers, attention, SSM/RWKV blocks, assembly."""
