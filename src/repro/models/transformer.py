"""Model assembly: ModelConfig → init / full-pass / prefill / decode.

One code path serves all 10 assigned architectures.  The layer stack is a
``lax.scan`` over SUPER-BLOCKS (one period of ``cfg.block_pattern``), so
the HLO is O(period), not O(num_layers) — essential for compile time on
the 512-device dry-run and the standard production pattern for
homogeneous stacks.

Block kinds (see ModelConfig.block_pattern):
  attn / local / global   GQA attention (+ window / softcap variants) + MLP
  dense                   same as attn (name used in MoE interleaves)
  moe                     attention + HetuMoE FFN (core/moe) [+ shared MLP]
  mamba                   Mamba-2 block
  mamba_sa                Mamba-2 block + zamba2-style SHARED attention
                          block (one param set for all occurrences,
                          per-occurrence LoRA on its input)
  rwkv                    RWKV-6 time-mix + channel-mix

Sharding: the model runs under jit/SPMD; activations get
``with_sharding_constraint`` hints at block boundaries (batch →
data axes, ffn/heads → model).  The MoE block is the explicit-collective
island (shard_map) per the paper.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import moe as moe_lib
from repro.core.compat import shard_map
from repro.core.config import ModelConfig
from repro.models import attention as attn_lib
from repro.models import layers, mamba2, rwkv6

LORA_R = 16   # zamba2 shared-block per-occurrence adapter rank


# ---------------------------------------------------------------------------
# sharding hints
# ---------------------------------------------------------------------------

def _dp_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def use_expert_tp() -> bool:
    """Expert-TP decode toggle (§Perf, llama4/dbrx decode hillclimb).
    REPRO_EXPERT_TP=0 reverts to ZeRO-3 gathered expert weights."""
    import os
    return os.environ.get("REPRO_EXPERT_TP", "1") == "1"


def decode_expert_tp_axis(mesh) -> Optional[str]:
    """The expert-TP axis the decode path shards the expert f dim over,
    or None.  One decision point for the MoE decode block AND the
    serving step-builder (``serving/engine.py``), so both agree on the
    decode-time collective layout — composes with ``dispatch="grouped"``
    (the ragged-aware TP gather, PR 4), which is the supported serving
    configuration for the tiny ragged decode batches."""
    if not use_expert_tp() or mesh is None:
        return None
    if "data" in mesh.axis_names:
        return "data"
    import warnings
    warnings.warn(
        f"expert TP requested (REPRO_EXPERT_TP) but mesh "
        f"{mesh.axis_names} has no 'data' axis — decoding "
        f"without expert tensor parallelism")
    return None


def shard_act(x: jax.Array, mesh, kind: str = "blk") -> jax.Array:
    """Activation sharding hint.  kind: blk (B,S,d) | logits (B,S,V).

    Block-boundary activations are SEQUENCE-PARALLEL (S over model) when
    the sequence divides the axis — Megatron-SP — which divides saved-
    for-backward activation memory by the model-axis size; XLA inserts
    the all-gather before attention where the full sequence is needed.
    """
    if mesh is None or mesh.devices.size == 1:
        return x
    dp = _dp_axes(mesh)
    msize = mesh.shape.get("model", 1)
    if kind == "logits":
        vdim = "model" if x.shape[-1] % msize == 0 else None
        spec = P(dp, None, vdim)
    else:
        sdim = "model" if (x.ndim == 3 and x.shape[1] % msize == 0
                           and x.shape[1] > 1) else None
        spec = P(dp, sdim, None)
    return lax.with_sharding_constraint(x, jax.sharding.NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_block(rng: jax.Array, kind: str, cfg: ModelConfig) -> Dict[str, Any]:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(rng, 6)
    if kind in ("attn", "local", "global", "dense"):
        return {"ln1": layers.init_norm(d),
                "attn": attn_lib.init_attention(ks[0], cfg.attention, d),
                "ln2": layers.init_norm(d),
                "mlp": layers.init_mlp(ks[1], d, f, cfg.act)}
    if kind == "moe":
        p = {"ln1": layers.init_norm(d),
             "attn": attn_lib.init_attention(ks[0], cfg.attention, d),
             "ln2": layers.init_norm(d),
             "moe": moe_lib.init_moe_params(
                 ks[1], cfg.moe, d, cfg.moe.d_ff_expert or f,
                 cfg.moe.num_experts, act=cfg.act, dtype=jnp.float32)}
        if cfg.moe.num_shared_experts:
            p["shared_mlp"] = layers.init_mlp(
                ks[2], d, (cfg.moe.d_ff_expert or f) * cfg.moe.num_shared_experts,
                cfg.act)
        return p
    if kind == "mamba":
        return {"ln1": layers.init_norm(d),
                "mamba": mamba2.init_mamba_block(ks[0], cfg.ssm, d)}
    if kind == "mamba_sa":
        return {"ln1": layers.init_norm(d),
                "mamba": mamba2.init_mamba_block(ks[0], cfg.ssm, d),
                "sa_ln": layers.init_norm(d),
                "sa_lora_a": jax.random.normal(ks[1], (d, LORA_R), jnp.float32) * d ** -0.5,
                "sa_lora_b": jnp.zeros((LORA_R, d), jnp.float32)}
    if kind == "rwkv":
        return {"ln1": layers.init_norm(d),
                "rwkv": rwkv6.init_rwkv_block(ks[0], cfg.rwkv, d),
                "ln2": layers.init_norm(d),
                "mlp": layers.init_mlp(ks[1], d, f, cfg.act)}
    raise ValueError(kind)


def init_model(rng: jax.Array, cfg: ModelConfig) -> Dict[str, Any]:
    nsb = cfg.num_super_blocks
    k_embed, k_blocks, k_head, k_shared = jax.random.split(rng, 4)
    # stacked per-kind block params: init one per super-block, stack leaves
    block_keys = jax.random.split(k_blocks, nsb)

    def one_super(k):
        kk = jax.random.split(k, len(cfg.block_pattern))
        return tuple(_init_block(kk[j], kind, cfg)
                     for j, kind in enumerate(cfg.block_pattern))

    blocks = jax.tree.map(lambda *xs: jnp.stack(xs),
                          *[one_super(k) for k in block_keys])
    params: Dict[str, Any] = {"blocks": blocks,
                              "final_norm": layers.init_norm(cfg.d_model)}
    if cfg.frontend is None:
        params["embed"] = layers.init_embedding(k_embed, cfg.vocab_size, cfg.d_model)
    if not cfg.tie_embeddings or cfg.frontend is not None:
        params["lm_head"] = (jax.random.normal(
            k_head, (cfg.d_model, cfg.vocab_size), jnp.float32)
            * cfg.d_model ** -0.5)
    if "mamba_sa" in cfg.block_pattern:
        params["shared_attn"] = {
            "ln": layers.init_norm(cfg.d_model),
            "attn": attn_lib.init_attention(k_shared, cfg.attention, cfg.d_model)}
    return params


# ---------------------------------------------------------------------------
# per-block application (mode: "full" with optional cache collect | "decode")
# ---------------------------------------------------------------------------

def _block_window(kind: str, cfg: ModelConfig, long_context: bool) -> Optional[int]:
    if kind == "local":
        return cfg.local_window
    if kind == "global" and long_context:
        # documented long_500k variant: global layers capped to local_window
        return cfg.local_window
    return cfg.attention.window if cfg.attention else None


def _apply_attn_mlp(bp, shared, x, kind, cfg: ModelConfig, mesh, mode, cache,
                    positions, long_context, rng):
    win = _block_window(kind, cfg, long_context)
    causal = not cfg.encoder_only
    h = layers.rms_norm(x, bp["ln1"], cfg.norm_eps)
    if mode == "decode":
        ring = win is not None and cache["k"].shape[1] == win
        a, cache = attn_lib.decode_attention(bp["attn"], h, cache, cfg.attention,
                                             ring=ring, window=win)
    else:
        a, kv = attn_lib.full_attention(bp["attn"], h, cfg.attention,
                                        positions=positions, causal=causal,
                                        window=win, mesh=mesh)
        if cache is not None:
            ring = win is not None and cache["k"].shape[1] == win
            cache = attn_lib.fill_cache(cache, kv, ring=ring)
    x = x + a
    aux = jnp.zeros((), jnp.float32)
    h = layers.rms_norm(x, bp["ln2"], cfg.norm_eps)
    if kind == "moe":
        # expert TP needs a data axis to shard f over; sharded_moe_apply
        # rejects axes missing from the mesh rather than silently no-op'ing
        tp = decode_expert_tp_axis(mesh) if mode == "decode" else None
        y, aux, _ = moe_lib.sharded_moe_apply(
            mesh, cfg.moe, bp["moe"], h, num_experts=cfg.moe.num_experts,
            act=cfg.act, rng=rng, expert_tp_axis=tp)
        if "shared_mlp" in bp:
            y = y + layers.apply_mlp(bp["shared_mlp"], h, cfg.act)
    else:
        y = layers.apply_mlp(bp["mlp"], h, cfg.act)
    return x + y, cache, aux


def _apply_block(j, kind, bp, shared, x, cfg, mesh, mode, cache, positions,
                 long_context, rng):
    zero = jnp.zeros((), jnp.float32)
    if kind in ("attn", "local", "global", "dense", "moe"):
        return _apply_attn_mlp(bp, shared, x, kind, cfg, mesh, mode, cache,
                               positions, long_context, rng)
    if kind in ("mamba", "mamba_sa"):
        h = layers.rms_norm(x, bp["ln1"], cfg.norm_eps)
        if mode == "decode":
            y, mstate = mamba2.mamba_decode_step(bp["mamba"], h,
                                                 cache["mamba"], cfg.ssm,
                                                 cfg.d_model)
        else:
            y, mstate = mamba2.mamba_forward(bp["mamba"], h, cfg.ssm,
                                             cfg.d_model, mesh=mesh)
            mstate = mstate if cache is not None else None
        x = x + y
        if kind == "mamba_sa":
            # zamba2: the SHARED attention block, LoRA-adapted per occurrence
            h = layers.rms_norm(x, bp["sa_ln"], cfg.norm_eps)
            h = h + (h @ bp["sa_lora_a"].astype(h.dtype)) @ bp["sa_lora_b"].astype(h.dtype)
            h = layers.rms_norm(h, shared["ln"], cfg.norm_eps)
            win = cfg.local_window if long_context else cfg.attention.window
            if mode == "decode":
                a, sa_cache = attn_lib.decode_attention(
                    shared["attn"], h, cache["sa"], cfg.attention,
                    ring=cache["sa"]["k"].shape[1] == win, window=win)
            else:
                a, kv = attn_lib.full_attention(shared["attn"], h, cfg.attention,
                                                positions=positions, window=win,
                                                mesh=mesh)
                sa_cache = attn_lib.fill_cache(
                    cache["sa"], kv, ring=cache["sa"]["k"].shape[1] == win) \
                    if cache is not None else None
            x = x + a
            new_cache = {"mamba": mstate, "sa": sa_cache} \
                if (cache is not None or mode == "decode") else None
        else:
            new_cache = {"mamba": mstate} if (cache is not None or mode == "decode") else None
        return x, new_cache, zero
    if kind == "rwkv":
        h = layers.rms_norm(x, bp["ln1"], cfg.norm_eps)
        if mode == "decode":
            y, rstate = rwkv6.rwkv_decode_step(bp["rwkv"], h, cache["rwkv"],
                                               cfg.rwkv)
        else:
            y, s = rwkv6.rwkv_time_mix(bp["rwkv"], h, cfg.rwkv)
            rstate = {"s": s, "x_last": h[:, -1].astype(jnp.float32)} \
                if cache is not None else None
        x = x + y
        h = layers.rms_norm(x, bp["ln2"], cfg.norm_eps)
        x = x + layers.apply_mlp(bp["mlp"], h, cfg.act)   # channel mix
        new_cache = {"rwkv": rstate} if (cache is not None or mode == "decode") else None
        return x, new_cache, zero
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# cache construction
# ---------------------------------------------------------------------------

def init_caches(cfg: ModelConfig, batch: int, cache_len: int, *,
                long_context: bool = False, dtype=jnp.bfloat16):
    """Per-super-block stacked caches for decode/prefill."""

    def one(kind):
        if kind in ("attn", "local", "global", "dense", "moe"):
            win = _block_window(kind, cfg, long_context)
            L = min(cache_len, win) if win is not None else cache_len
            return attn_lib.init_cache(cfg.attention, batch, L, cfg.d_model, dtype)
        if kind in ("mamba", "mamba_sa"):
            c = {"mamba": mamba2.init_mamba_state(cfg.ssm, batch, cfg.d_model)}
            if kind == "mamba_sa":
                win = cfg.local_window if long_context else cfg.attention.window
                L = min(cache_len, win) if win is not None else cache_len
                c["sa"] = attn_lib.init_cache(cfg.attention, batch, L,
                                              cfg.d_model, dtype)
            return c
        if kind == "rwkv":
            return {"rwkv": init_rwkv(cfg, batch)}
        raise ValueError(kind)

    def init_rwkv(cfg, batch):
        return rwkv6.init_rwkv_state(cfg.rwkv, batch, cfg.d_model)

    single = tuple(one(k) for k in cfg.block_pattern)
    nsb = cfg.num_super_blocks
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (nsb, *a.shape)).copy(), single)


# ---------------------------------------------------------------------------
# full / prefill / decode passes
# ---------------------------------------------------------------------------

def _embed_inputs(params, cfg: ModelConfig, inputs: jax.Array, dtype, mesh=None):
    if cfg.frontend is not None:
        return inputs.astype(dtype)     # precomputed frame/patch embeddings
    table = params["embed"]
    msize = mesh.shape.get("model", 1) if mesh is not None else 1
    dp_size = 1
    if mesh is not None:
        for a in _dp_axes(mesh):
            dp_size *= mesh.shape[a]
    if msize > 1 and table.shape[0] % msize == 0 \
            and inputs.shape[0] % dp_size == 0:
        # vocab-parallel embedding (Megatron): local masked gather + psum.
        # A plain sharded gather makes XLA materialize the full unsharded
        # (V, d) gradient scatter in backward — 2.3 GiB/dev at dbrx scale.
        dp = _dp_axes(mesh)

        def local(tbl, ids):
            m = lax.axis_index("model")
            vloc = tbl.shape[0]
            rel = ids - m * vloc
            ok = (rel >= 0) & (rel < vloc)
            rows = tbl.astype(dtype)[jnp.clip(rel, 0, vloc - 1)]
            return lax.psum(jnp.where(ok[..., None], rows, 0), "model")

        x = shard_map(
            local, mesh=mesh,
            in_specs=(P("model", None), P(dp)),
            out_specs=P(dp, None, None), check_vma=False,
        )(table, inputs)
        if cfg.scale_embeddings:
            x = x * jnp.asarray(cfg.d_model ** 0.5, dtype)
        return x
    return layers.embed(table, inputs, dtype, cfg.scale_embeddings)


def forward(params: Dict[str, Any], inputs: jax.Array, cfg: ModelConfig, *,
            mesh=None, rng: Optional[jax.Array] = None,
            caches=None, collect_caches: bool = False,
            long_context: bool = False, remat: str = "none",
            positions: Optional[jax.Array] = None):
    """Full-sequence pass (train / prefill).

    inputs: (B, S) int tokens, or (B, S, d) embeddings for frontend archs.
    Returns (hidden (B,S,d), aux_loss, caches|None).
    """
    dtype = jnp.dtype(cfg.dtype)
    x = _embed_inputs(params, cfg, inputs, dtype, mesh)
    B, S = x.shape[:2]
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)
    if rng is None:
        rng = jax.random.PRNGKey(0)
    shared = params.get("shared_attn")
    x = shard_act(x, mesh)

    def super_body(carry, xs):
        x, aux, rng = carry
        bparams, cache_in = xs
        rng, *rks = jax.random.split(rng, len(cfg.block_pattern) + 1)
        new_caches = []
        for j, kind in enumerate(cfg.block_pattern):
            c_in = cache_in[j] if cache_in is not None else None
            x, c_out, a = _apply_block(j, kind, bparams[j], shared, x, cfg,
                                       mesh, "full", c_in, positions,
                                       long_context, rks[j])
            x = shard_act(x, mesh)
            aux = aux + a
            new_caches.append(c_out)
        out_caches = tuple(new_caches) if cache_in is not None else None
        return (x, aux, rng), out_caches

    body = super_body
    if remat == "block":
        body = jax.checkpoint(super_body)
    elif remat == "full":
        body = jax.checkpoint(super_body,
                              policy=jax.checkpoint_policies.nothing_saveable)

    if collect_caches and caches is None:
        caches = init_caches(cfg, B, S, long_context=long_context, dtype=dtype)
    xs = (params["blocks"], caches)
    (x, aux, _), out_caches = lax.scan(body, (x, jnp.zeros((), jnp.float32), rng), xs)
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux, out_caches


def logits_from_hidden(params, cfg: ModelConfig, h: jax.Array, mesh=None):
    w = params["embed"].T if cfg.tie_embeddings and cfg.frontend is None \
        else params["lm_head"]
    logits = h @ w.astype(h.dtype)
    if cfg.final_softcap:
        logits = layers.softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return shard_act(logits, mesh, "logits")


def decode_step(params: Dict[str, Any], token: jax.Array, caches, cfg: ModelConfig,
                *, mesh=None, rng: Optional[jax.Array] = None,
                long_context: bool = False):
    """One-token serve step.  token (B,1) ids or (B,1,d) embeddings;
    caches as returned by init_caches/prefill.  Returns (logits (B,1,V), caches)."""
    dtype = jnp.dtype(cfg.dtype)
    x = _embed_inputs(params, cfg, token, dtype, mesh)
    if rng is None:
        rng = jax.random.PRNGKey(0)
    shared = params.get("shared_attn")

    def super_body(carry, xs):
        x, aux, rng = carry
        bparams, cache_in = xs
        rng, *rks = jax.random.split(rng, len(cfg.block_pattern) + 1)
        new_caches = []
        for j, kind in enumerate(cfg.block_pattern):
            x, c_out, a = _apply_block(j, kind, bparams[j], shared, x, cfg,
                                       mesh, "decode", cache_in[j], None,
                                       long_context, rks[j])
            aux = aux + a
            new_caches.append(c_out)
        return (x, aux, rng), tuple(new_caches)

    (x, _, _), new_caches = lax.scan(
        super_body, (x, jnp.zeros((), jnp.float32), rng),
        (params["blocks"], caches))
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return logits_from_hidden(params, cfg, x, mesh), new_caches
