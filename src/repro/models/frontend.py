"""Modality frontends — STUBS per the assignment carve-out.

``[audio]`` and ``[vlm]`` architectures specify the transformer backbone
only; the conv feature extractor (hubert) and the ViT+projector
(internvl2) are replaced by providers of correctly-shaped precomputed
embeddings.  ``input_specs()`` in launch/dryrun.py consumes these shapes;
the synthetic data pipeline generates matching random embeddings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig


def frontend_embedding_shape(cfg: ModelConfig, batch: int, seq: int):
    """Shape of the precomputed embedding stream the backbone consumes.

    audio  — HuBERT conv extractor output: one frame embedding per 20 ms,
             projected to d_model (stub: (B, S, d) directly).
    vision — InternViT patch embeddings after the MLP projector,
             interleaved with text-token embeddings (stub: the merged
             (B, S, d) stream).
    """
    assert cfg.frontend in ("audio", "vision"), cfg.frontend
    return (batch, seq, cfg.d_model)


def synthetic_embeddings(rng: jax.Array, cfg: ModelConfig, batch: int, seq: int,
                         dtype=jnp.bfloat16) -> jax.Array:
    return jax.random.normal(
        rng, frontend_embedding_shape(cfg, batch, seq), dtype) * 0.02
