"""RWKV-6 "Finch" time-mix + channel-mix (arXiv:2404.05892).

The defining feature vs RWKV-5/linear attention: the per-channel decay
``w_t`` is DATA-DEPENDENT (a low-rank MLP of the token-shifted input), as
is the token-shift interpolation itself.

Recurrence per head (state S ∈ R^{hd×hd}):

    S_t = diag(w_t) · S_{t-1} + k_tᵀ v_t
    o_t = r_t · (S_{t-1} + diag(u) k_tᵀ v_t)

Training uses the chunked form: within a chunk of L tokens the pairwise
decay factors as exp(cum_{t-1} - cum_s) with cum = Σ log w, so the intra-
chunk term is a masked (r̃ k̃ᵀ) matmul — O(L²·hd) MXU work — and the
inter-chunk term is carried by a ``lax.scan`` over chunk states.  The
``exp(-cum)`` side is clipped at e³⁰ (contributions beyond that decay
level are < e⁻³⁰ — below bf16 resolution anyway).

Decode is the raw recurrence: O(1) time and memory per token — the reason
rwkv6 runs the 524k-token decode shape.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.config import RWKVConfig
from repro.models.layers import rms_norm

_CLIP = 30.0


def init_rwkv_block(rng: jax.Array, cfg: RWKVConfig, d: int) -> Dict[str, jax.Array]:
    ks = jax.random.split(rng, 12)
    s = d ** -0.5
    H = d // cfg.head_dim
    p = {
        # token-shift interpolation: static μ per channel for (r,k,v,w,g)
        # + data-dependent LoRA correction (the "6" in RWKV-6)
        "mu": jax.random.uniform(ks[0], (5, d), jnp.float32),
        "mix_a": jax.random.normal(ks[1], (d, cfg.mix_lora * 5), jnp.float32) * s,
        "mix_b": jax.random.normal(ks[2], (5, cfg.mix_lora, d), jnp.float32)
                 * cfg.mix_lora ** -0.5,
        "wr": jax.random.normal(ks[3], (d, d), jnp.float32) * s,
        "wk": jax.random.normal(ks[4], (d, d), jnp.float32) * s,
        "wv": jax.random.normal(ks[5], (d, d), jnp.float32) * s,
        "wg": jax.random.normal(ks[6], (d, d), jnp.float32) * s,
        "wo": jax.random.normal(ks[7], (d, d), jnp.float32) * s,
        # data-dependent decay: w_t = exp(-exp(w0 + LoRA(x_w)))
        "w0": jnp.full((d,), -6.0, jnp.float32) +
              jax.random.normal(ks[8], (d,), jnp.float32) * 0.1,
        "decay_a": jax.random.normal(ks[9], (d, cfg.decay_lora), jnp.float32) * s,
        "decay_b": jax.random.normal(ks[10], (cfg.decay_lora, d), jnp.float32)
                   * cfg.decay_lora ** -0.5,
        "u": jax.random.normal(ks[11], (d,), jnp.float32) * 0.1,  # bonus
        "ln_x": jnp.ones((d,), jnp.float32),       # per-head groupnorm scale
    }
    # channel mix (RWKV's FFN analogue) lives in transformer.py as an MLP
    return p


def _mix_inputs(p, x, x_prev):
    """Data-dependent token shift → the 5 mixed streams (r,k,v,w,g).
    x (B,S,d); x_prev is x shifted right one token (B,S,d)."""
    dt = x.dtype
    d = x.shape[-1]
    delta = x_prev - x
    # base mix + low-rank data-dependent correction
    lora = jnp.tanh(x @ p["mix_a"].astype(dt))                  # (B,S,5*r)
    lora = lora.reshape(*x.shape[:-1], 5, -1)
    corr = jnp.einsum("bsfr,frd->bsfd", lora, p["mix_b"].astype(dt))
    mix = p["mu"].astype(dt)[None, None] + corr                  # (B,S,5,d)
    return x[..., None, :] + delta[..., None, :] * mix           # (B,S,5,d)


def _rkvwg(p, x, x_prev, H, hd):
    m = _mix_inputs(p, x, x_prev)
    dt = x.dtype
    B, S = x.shape[:2]
    r = (m[..., 0, :] @ p["wr"].astype(dt)).reshape(B, S, H, hd)
    k = (m[..., 1, :] @ p["wk"].astype(dt)).reshape(B, S, H, hd)
    v = (m[..., 2, :] @ p["wv"].astype(dt)).reshape(B, S, H, hd)
    logw = -jnp.exp(jnp.clip(
        (m[..., 3, :].astype(jnp.float32) @ p["decay_a"]) @ p["decay_b"]
        + p["w0"], -8.0, 1.0)).reshape(B, S, H, hd)              # log w_t < 0
    g = jax.nn.silu(m[..., 4, :] @ p["wg"].astype(dt))
    return r, k, v, logw, g


def _chunk_scan(r, k, v, logw, u, state):
    """One chunk: r,k,v (B,L,H,hd) f32, logw (B,L,H,hd), state (B,H,hd,hd).
    Returns (o (B,L,H,hd), new_state)."""
    B, L, H, hd = r.shape
    cum = jnp.cumsum(logw, axis=1)                               # (B,L,H,hd)
    cum_in = cum - logw                                           # Σ_{i<t}
    r_dec = r * jnp.exp(cum_in)                                   # r̃_t
    k_dec = k * jnp.exp(jnp.minimum(-cum, _CLIP))                 # k̃_s
    # inter-chunk: o_t += r̃_t · S0
    o = jnp.einsum("blhc,bhcv->blhv", r_dec, state)
    # intra-chunk: strictly-lower pairwise + diagonal bonus term
    scores = jnp.einsum("blhc,bmhc->bhlm", r_dec, k_dec)
    mask = jnp.tril(jnp.ones((L, L), bool), -1)
    scores = jnp.where(mask[None, None], scores, 0.0)
    o = o + jnp.einsum("bhlm,bmhv->blhv", scores, v)
    # diagonal bonus: o_t += (r_t · (u ⊙ k_t)) v_t
    o = o + jnp.sum(r * (u[None, None] * k), axis=-1, keepdims=True) * v
    # state: S' = diag(A_L) S0 + Σ_s diag(A_L/A_s) k_sᵀ v_s
    decay_all = jnp.exp(cum[:, -1])                               # (B,H,hd)
    k_carry = k * jnp.exp(jnp.minimum(cum[:, -1:] - cum, _CLIP))
    new_state = decay_all[..., None] * state + \
        jnp.einsum("blhc,blhv->bhcv", k_carry, v)
    return o, new_state


def rwkv_time_mix(p: Dict[str, jax.Array], x: jax.Array, cfg: RWKVConfig,
                  *, x_last: jax.Array = None
                  ) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence (train/prefill) pass.  x (B,S,d) → (y, final_state)."""
    B, S, d = x.shape
    H, hd = d // cfg.head_dim, cfg.head_dim
    x_prev = jnp.concatenate(
        [jnp.zeros_like(x[:, :1]) if x_last is None else x_last[:, None],
         x[:, :-1]], axis=1)
    r, k, v, logw, g = _rkvwg(p, x, x_prev, H, hd)
    u = p["u"].reshape(H, hd)
    L = min(cfg.chunk_size, S)
    while S % L:                 # largest divisor of S ≤ chunk_size
        L -= 1
    nc = S // L

    def to32(a):
        return a.astype(jnp.float32).reshape(B, nc, L, H, hd).transpose(1, 0, 2, 3, 4)

    state0 = jnp.zeros((B, H, hd, hd), jnp.float32)

    # checkpoint: see mamba2.py — avoids stacking per-chunk pairwise
    # score residuals across the chunk scan in backward
    @jax.checkpoint
    def body(state, inp):
        rc, kc, vc, wc = inp
        o, state = _chunk_scan(rc, kc, vc, wc, u, state)
        return state, o

    state, os = lax.scan(body, state0, (to32(r), to32(k), to32(v), to32(logw)))
    o = os.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)
    o = rms_norm(o, jnp.broadcast_to(p["ln_x"].reshape(H, hd) - 1.0, o.shape[-2:]))
    y = (o.reshape(B, S, d).astype(x.dtype) * g) @ p["wo"].astype(x.dtype)
    return y, state


def init_rwkv_state(cfg: RWKVConfig, batch: int, d: int):
    H, hd = d // cfg.head_dim, cfg.head_dim
    return {"s": jnp.zeros((batch, H, hd, hd), jnp.float32),
            "x_last": jnp.zeros((batch, d), jnp.float32)}


def rwkv_decode_step(p: Dict[str, jax.Array], x: jax.Array, state, cfg: RWKVConfig
                     ) -> Tuple[jax.Array, dict]:
    """One token.  x (B,1,d); state {s (B,H,hd,hd), x_last (B,d)}."""
    B, one, d = x.shape
    H, hd = d // cfg.head_dim, cfg.head_dim
    x_prev = state["x_last"].astype(x.dtype)[:, None]
    r, k, v, logw, g = _rkvwg(p, x, x_prev, H, hd)
    r, k, v = (a.astype(jnp.float32)[:, 0] for a in (r, k, v))     # (B,H,hd)
    w = jnp.exp(logw[:, 0])                                         # (B,H,hd)
    u = p["u"].reshape(H, hd)
    S0 = state["s"]
    kv = jnp.einsum("bhc,bhv->bhcv", k, v)
    o = jnp.einsum("bhc,bhcv->bhv", r, S0 + u[None, :, :, None] * kv)
    s_new = w[..., None] * S0 + kv
    o = rms_norm(o, jnp.broadcast_to(p["ln_x"].reshape(H, hd) - 1.0, (H, hd)))
    y = (o.reshape(B, 1, d).astype(x.dtype) * g) @ p["wo"].astype(x.dtype)
    return y, {"s": s_new, "x_last": x[:, 0].astype(jnp.float32)}
