"""Grouped-query attention: RoPE, sliding windows, softcaps, KV caches.

Covers every attention variant in the assigned zoo:

* GQA with arbitrary H/KV ratio (yi 32/4, starcoder2 24/2, …)
* RoPE (configurable θ) or none (hubert uses learned conv pos — stubbed
  into the frontend embeddings)
* sliding-window masks (h2o-danube3 SWA, gemma2 local layers)
* gemma2 attention-logit softcap
* encoder (bidirectional) mode for hubert
* decode caches: linear (append-at-pos) and RING (bounded window memory —
  what makes SWA archs eligible for the 524k-token decode shape)

The full pass is q-chunked (flash-style accumulation-free streaming over
query blocks via ``lax.scan``) so the (Q, S) score matrix never exceeds
``q_chunk · S`` per head group — the memory knob for prefill_32k.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.compat import shard_map
from repro.core.config import AttentionConfig
from repro.models.layers import rms_norm, softcap as _softcap


def init_attention(rng: jax.Array, cfg: AttentionConfig, d_model: int
                   ) -> Dict[str, jax.Array]:
    hd = cfg.head_dim or d_model // cfg.num_heads
    ks = jax.random.split(rng, 4)
    s = d_model ** -0.5
    p = {
        "wq": jax.random.normal(ks[0], (d_model, cfg.num_heads * hd), jnp.float32) * s,
        "wk": jax.random.normal(ks[1], (d_model, cfg.num_kv_heads * hd), jnp.float32) * s,
        "wv": jax.random.normal(ks[2], (d_model, cfg.num_kv_heads * hd), jnp.float32) * s,
        "wo": jax.random.normal(ks[3], (cfg.num_heads * hd, d_model), jnp.float32)
              * (cfg.num_heads * hd) ** -0.5,
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), jnp.float32)
        p["k_norm"] = jnp.zeros((hd,), jnp.float32)
    return p


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x (..., S, n, hd), positions (..., S) → rotated x."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq      # (..., S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _qkv(params, x, cfg: AttentionConfig, positions):
    B, S, d = x.shape
    hd = cfg.head_dim or d // cfg.num_heads
    dt = x.dtype
    q = (x @ params["wq"].astype(dt)).reshape(B, S, cfg.num_heads, hd)
    k = (x @ params["wk"].astype(dt)).reshape(B, S, cfg.num_kv_heads, hd)
    v = (x @ params["wv"].astype(dt)).reshape(B, S, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    if cfg.use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _attend(q, k, v, q_pos, k_pos, *, causal: bool,
            window: Optional[int], cap: Optional[float], scale: float):
    """q (B,Q,H,hd), k/v (B,S,KV,hd), positions (Q,)/(S,); k_pos < 0 ⇒ slot
    invalid.  Returns (B,Q,H,hd)."""
    B, Q, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Q, KV, G, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if cap is not None:
        s = _softcap(s, cap)
    m = (k_pos >= 0)[None, :]
    if causal:
        m = m & (k_pos[None, :] <= q_pos[:, None])
    if window is not None:
        m = m & (k_pos[None, :] > q_pos[:, None] - window)
    s = jnp.where(m[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v)
    return o.reshape(B, Q, H, hd)


def full_attention(params: Dict[str, jax.Array], x: jax.Array,
                   cfg: AttentionConfig, *, positions: jax.Array,
                   causal: bool = True, window: Optional[int] = None,
                   q_chunk: int = 512, mesh=None
                   ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Train/prefill pass.  Returns (y, kv) — kv reused to build caches.

    §Perf note (dbrx hillclimb H1, two REFUTED variants recorded in
    EXPERIMENTS.md): pinning k/v (or the pre-qkv input) S-replicated to
    hoist the sequence-parallel gather out of the q-chunk scan made XLA
    insert per-chunk reshards (+128 GB/dev) or replicate the global
    batch (+1 TB/dev).  The baseline per-chunk staging stands; the
    winning lever is the FLASH path (H3) below."""
    B, S, d = x.shape
    q, k, v = _qkv(params, x, cfg, positions[None, :])
    hd = q.shape[-1]
    scale = hd ** -0.5
    cap = cfg.attn_softcap
    win = window if window is not None else cfg.window
    if use_flash() and S > q_chunk:
        o = _flash_path(q, k, v, positions, mesh, causal=causal, window=win,
                        cap=cap, scale=scale, q_chunk=q_chunk)
        y = o.reshape(B, S, -1).astype(x.dtype) @ params["wo"].astype(x.dtype)
        return y, {"k": k, "v": v}
    if S <= q_chunk:
        o = _attend(q, k, v, positions, positions,
                    causal=causal, window=win, cap=cap, scale=scale)
    else:
        assert S % q_chunk == 0, (S, q_chunk)
        nc = S // q_chunk
        qs = q.reshape(B, nc, q_chunk, *q.shape[2:]).transpose(1, 0, 2, 3, 4)
        ps = positions.reshape(nc, q_chunk)

        # checkpoint: otherwise scan's VJP stacks every chunk's softmax
        # residuals — the full (S, S) score tensor in f32 (flash-attention
        # recomputes scores in backward for the same reason)
        @jax.checkpoint
        def body(_, qp):
            qi, pi = qp
            return None, _attend(qi, k, v, pi, positions,
                                 causal=causal, window=win, cap=cap, scale=scale)

        _, os = lax.scan(body, None, (qs, ps))
        o = os.transpose(1, 0, 2, 3, 4).reshape(B, S, q.shape[2], hd)
    y = o.reshape(B, S, -1).astype(x.dtype) @ params["wo"].astype(x.dtype)
    return y, {"k": k, "v": v}


def use_flash() -> bool:
    """Flash-attention Pallas path toggle (§Perf H3).  On by default for
    long sequences; REPRO_FLASH=0 reverts to the chunked-jnp baseline."""
    import os
    return os.environ.get("REPRO_FLASH", "1") == "1"


def _flash_path(q, k, v, positions, mesh, *, causal, window, cap, scale,
                q_chunk):
    """Run the flash kernel, context-parallel when a mesh is present:
    q's sequence shards over `model` (each rank computes its query slice
    against the full k/v — GQA-agnostic, divides for every arch), batch
    over the data axes; k/v replicate over `model` (gathered ONCE at the
    shard_map boundary — the fix per-chunk staging couldn't achieve)."""
    from repro.kernels.flash_attention import flash_attention
    B, S, H, hd = q.shape
    qh = q.transpose(0, 2, 1, 3)                   # (B, H, S, hd)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    k_pos = positions
    interpret = jax.default_backend() != "tpu"
    msize = mesh.shape.get("model", 1) if mesh is not None else 1
    if mesh is None or mesh.devices.size == 1 or S % msize or msize <= 1:
        o = flash_attention(qh, kh, vh, positions, k_pos, scale, causal,
                            window, cap, min(q_chunk, 512), interpret)
        return o.transpose(0, 2, 1, 3)
    from jax.sharding import PartitionSpec as P
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))

    def local(qh, kh, vh, qp, kp):
        return flash_attention(qh, kh, vh, qp, kp, scale, causal, window,
                               cap, min(q_chunk, 512), interpret)

    o = shard_map(
        local, mesh=mesh,
        in_specs=(P(dp, None, "model", None), P(dp, None, None, None),
                  P(dp, None, None, None), P("model"), P(None)),
        out_specs=P(dp, None, "model", None), check_vma=False,
    )(qh, kh, vh, positions, k_pos)
    return o.transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
# decode caches
# ---------------------------------------------------------------------------

def init_cache(cfg: AttentionConfig, batch: int, cache_len: int, d_model: int,
               dtype) -> Dict[str, jax.Array]:
    hd = cfg.head_dim or d_model // cfg.num_heads
    shape = (batch, cache_len, cfg.num_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "pos": jnp.zeros((), jnp.int32)}


def fill_cache(cache: Dict[str, jax.Array], kv: Dict[str, jax.Array],
               *, ring: bool) -> Dict[str, jax.Array]:
    """Write a prefill's (B, S, KV, hd) keys/values into the cache.

    Ring caches store position p at slot p % W, so decode's slot
    arithmetic continues seamlessly after an over-long prefill."""
    S = kv["k"].shape[1]
    W = cache["k"].shape[1]
    if ring and S >= W:
        # keep the last W positions, permuted so position p sits at p % W
        order = (jnp.arange(W) - S) % W        # slot s ← prompt row (s-S)%W
        kv = {n: kv[n][:, S - W:][:, order] for n in ("k", "v")}
        k = kv["k"].astype(cache["k"].dtype)
        v = kv["v"].astype(cache["v"].dtype)
        return {"k": k, "v": v, "pos": jnp.asarray(S, jnp.int32)}
    k = lax.dynamic_update_slice(cache["k"], kv["k"].astype(cache["k"].dtype),
                                 (0, 0, 0, 0))
    v = lax.dynamic_update_slice(cache["v"], kv["v"].astype(cache["v"].dtype),
                                 (0, 0, 0, 0))
    return {"k": k, "v": v, "pos": jnp.asarray(S, jnp.int32)}


def decode_attention(params: Dict[str, jax.Array], x: jax.Array,
                     cache: Dict[str, jax.Array], cfg: AttentionConfig, *,
                     ring: bool = False, window: Optional[int] = None,
                     ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode.  x (B, 1, d).  ``ring=True`` uses the bounded
    ring buffer (cache_len == window) — O(W) memory at any sequence length."""
    B, one, d = x.shape
    assert one == 1
    pos = cache["pos"]
    q, k_new, v_new = _qkv(params, x, cfg, pos[None, None])
    W = cache["k"].shape[1]
    slot = (pos % W) if ring else pos
    k = lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                 (0, slot, 0, 0))
    v = lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                 (0, slot, 0, 0))
    idx = jnp.arange(W, dtype=jnp.int32)
    if ring:
        # slot s holds position pos - ((pos - s) mod W); negatives invalid
        k_pos = pos - ((pos - idx) % W)
        k_pos = jnp.where(k_pos >= 0, k_pos, -1)
    else:
        k_pos = jnp.where(idx <= pos, idx, -1)
    win = window if window is not None else cfg.window
    o = _attend(q, k, v, pos[None], k_pos, causal=True, window=win,
                cap=cfg.attn_softcap, scale=q.shape[-1] ** -0.5)
    y = o.reshape(B, 1, -1).astype(x.dtype) @ params["wo"].astype(x.dtype)
    return y, {"k": k, "v": v, "pos": pos + 1}
