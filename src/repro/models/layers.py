"""Shared building blocks: norms, MLPs, embeddings, softcaps.

All modules are functional ``init_* / apply`` pairs over plain dicts.
Parameters are stored f32 (master copy); forward casts to the model's
compute dtype at use sites.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    """Gemma-2 logit soft-capping: cap·tanh(x/cap)."""
    return cap * jnp.tanh(x / cap)


def init_norm(d: int) -> jax.Array:
    return jnp.zeros((d,), jnp.float32)       # rmsnorm stores scale-1


def init_mlp(rng: jax.Array, d: int, f: int, act: str) -> Dict[str, jax.Array]:
    k1, k2 = jax.random.split(rng)
    cols = 2 * f if act in ("swiglu", "geglu") else f
    return {
        "w_in": jax.random.normal(k1, (d, cols), jnp.float32) * d ** -0.5,
        "w_out": jax.random.normal(k2, (f, d), jnp.float32) * f ** -0.5,
    }


def apply_mlp(params: Dict[str, jax.Array], x: jax.Array, act: str) -> jax.Array:
    dt = x.dtype
    h = x @ params["w_in"].astype(dt)
    if act in ("swiglu", "geglu"):
        u, g = jnp.split(h, 2, axis=-1)
        h = u * (jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g))
    elif act == "gelu":
        h = jax.nn.gelu(h)
    else:
        h = jax.nn.relu(h)
    return h @ params["w_out"].astype(dt)


def init_embedding(rng: jax.Array, vocab: int, d: int) -> jax.Array:
    return jax.random.normal(rng, (vocab, d), jnp.float32) * d ** -0.5


def embed(table: jax.Array, ids: jax.Array, dtype, scale: bool) -> jax.Array:
    x = table.astype(dtype)[ids]
    if scale:
        x = x * jnp.asarray(table.shape[-1] ** 0.5, dtype)
    return x


def unembed(table_or_head: jax.Array, x: jax.Array, *, tied: bool) -> jax.Array:
    w = table_or_head.astype(x.dtype)
    return x @ (w.T if tied else w)
