"""Synthetic deterministic data pipeline.

Generates language-model token streams (or frontend embedding streams for
the audio/vlm carve-outs) deterministically from ``(seed, step)`` — every
host/process computes its own shard without coordination, the standard
trick for reproducible multi-host input pipelines.  The "documents" are a
mixture of Zipf-distributed tokens with injected copy/repeat structure so
the LM loss is learnable (tests assert the loss actually falls).
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

import jax.numpy as jnp

from repro.core.config import ModelConfig, ShapeConfig


class SyntheticLM:
    """Deterministic synthetic LM batches.

    next_batch(step) → {"inputs": (B,S) i32 | (B,S,d) f32 for frontend
    archs, "targets": (B,S) i32, "loss_mask": (B,S) f32}
    """

    def __init__(self, cfg: ModelConfig, batch: int, seq_len: int,
                 seed: int = 0):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq_len
        self.seed = seed
        v = cfg.vocab_size
        # fixed zipf distribution over the vocabulary
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self._p = (1.0 / ranks) / np.sum(1.0 / ranks)

    def _tokens(self, rng: np.random.Generator, n: int) -> np.ndarray:
        toks = rng.choice(self.cfg.vocab_size, size=n, p=self._p)
        # inject copy structure: repeat a random span (learnable signal)
        if n >= 32:
            L = n // 4
            src = rng.integers(0, n - 2 * L)
            dst = src + L + rng.integers(0, max(n - src - 2 * L, 1))
            toks[dst:dst + L] = toks[src:src + L]
        return toks.astype(np.int32)

    def next_batch(self, step: int) -> Dict[str, jnp.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        B, S, cfg = self.batch, self.seq, self.cfg
        tok = np.stack([self._tokens(rng, S + 1) for _ in range(B)])
        batch = {
            "targets": jnp.asarray(tok[:, 1:]),
            "loss_mask": jnp.ones((B, S), jnp.float32),
        }
        if cfg.frontend is not None:
            # stubbed modality frontend: embeddings correlated with targets
            # through a fixed random projection (so loss is learnable)
            proj = np.random.default_rng(self.seed).standard_normal(
                (cfg.vocab_size, cfg.d_model)).astype(np.float32) * 0.02
            batch["inputs"] = jnp.asarray(proj[tok[:, :-1]])
        else:
            batch["inputs"] = jnp.asarray(tok[:, :-1])
        return batch


def make_batch_specs(cfg: ModelConfig, shape: ShapeConfig, dtype="bfloat16"):
    """ShapeDtypeStructs for one global batch (dry-run input stand-ins)."""
    import jax
    B, S = shape.global_batch, shape.seq_len
    if cfg.frontend is not None:
        inputs = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.dtype(dtype))
    else:
        inputs = jax.ShapeDtypeStruct((B, S), jnp.int32)
    return {"inputs": inputs,
            "targets": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "loss_mask": jax.ShapeDtypeStruct((B, S), jnp.float32)}
