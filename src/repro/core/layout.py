"""Data layout transform (paper §3.2, Fig. 4) — and its inverse.

After the gate decides token→expert, tokens bound for the same expert
must land in physically-contiguous memory before the AllToAll.  Two
interchangeable implementations produce bit-identical ``(E·C, d)``
buffers under the same priority rule (position-in-batch, slot-major):

``sort``    HetuMoE's approach — a stable sort over expert ids yields the
            position-within-expert, then a scatter packs the buffer.  On
            TPU the scatter is the Pallas ``layout_transform`` kernel
            (kernels/layout_transform.py); this module is the pure-jnp
            path the kernel is validated against.
``dense``   GShard/DeepSpeed baseline — position via cumsum of one-hots
            and a (S·K, E·C) one-hot einsum.  O(S·E·C) FLOPs vs the sort
            path's O(S·K·log(S·K)) + O(S·K·d) — the gap the paper's
            layout kernel exploits.

Dropped tokens (position ≥ capacity) get ``slot = -1`` and weight 0: the
residual connection carries them unchanged (Switch semantics).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.gating import GateOutput


class DispatchPlan(NamedTuple):
    """Static-shape routing plan for S tokens × K slots.

    ``slot``   (S, K) int32 — row in the (E·C, d) dispatch buffer, -1 dropped
    ``weight`` (S, K) f32   — combine weight, zeroed for dropped slots
    """
    slot: jax.Array
    weight: jax.Array


# ---------------------------------------------------------------------------
# plan construction — position-within-expert under capacity
# ---------------------------------------------------------------------------

def plan_sort(gate: GateOutput, num_experts: int, capacity: int) -> DispatchPlan:
    """HetuMoE path: stable argsort over expert ids.

    The stable sort keyed on expert id orders each expert's tokens by
    flattened (slot, token) index — slot-major priority (GShard/Switch
    semantics: every token's 1st choice outranks any 2nd choice) — so the
    first C stay, the rest drop.  Identical to :func:`plan_cumsum`.
    """
    S, K = gate.expert_index.shape
    flat_e = gate.expert_index.T.reshape(K * S)        # k-major flatten
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jax.ops.segment_sum(
        jnp.ones_like(flat_e), flat_e, num_segments=num_experts)
    starts = jnp.concatenate(
        [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    pos_sorted = jnp.arange(K * S, dtype=flat_e.dtype) - starts[sorted_e]
    pos = jnp.zeros_like(flat_e).at[order].set(pos_sorted)
    keep = pos < capacity
    slot = jnp.where(keep, flat_e * capacity + pos, -1).reshape(K, S).T
    weight = jnp.where((pos < capacity).reshape(K, S).T,
                       gate.combine_weights, 0.0)
    return DispatchPlan(slot.astype(jnp.int32), weight)


def plan_cumsum(gate: GateOutput, num_experts: int, capacity: int) -> DispatchPlan:
    """GShard baseline path: position via running one-hot cumsums,
    slot k accounting for all tokens of slots < k.  Identical output to
    :func:`plan_sort` (asserted in tests)."""
    S, K = gate.expert_index.shape
    oh = jax.nn.one_hot(gate.expert_index, num_experts, dtype=jnp.int32)  # (S,K,E)
    pos = jnp.zeros((S, K), jnp.int32)
    running = jnp.zeros((num_experts,), jnp.int32)
    for k in range(K):  # K is tiny (≤8) and static — unrolled
        csum = jnp.cumsum(oh[:, k, :], axis=0) - oh[:, k, :]      # excl. cumsum
        pos = pos.at[:, k].set(
            jnp.sum(oh[:, k, :] * (csum + running[None, :]), axis=-1))
        running = running + jnp.sum(oh[:, k, :], axis=0)
    keep = pos < capacity
    flat_e = gate.expert_index
    slot = jnp.where(keep, flat_e * capacity + pos, -1)
    weight = jnp.where(keep, gate.combine_weights, 0.0)
    return DispatchPlan(slot.astype(jnp.int32), weight)


# ---------------------------------------------------------------------------
# dispatch / combine execution
# ---------------------------------------------------------------------------

def dispatch_scatter(tokens: jax.Array, plan: DispatchPlan,
                     num_experts: int, capacity: int) -> jax.Array:
    """(S, d) → (E·C, d) via scatter (paper's layout-transform kernel)."""
    S, K = plan.slot.shape
    keep = plan.slot >= 0
    safe = jnp.where(keep, plan.slot, 0).reshape(S * K)
    src = jnp.where(keep.reshape(S * K, 1),
                    jnp.repeat(tokens, K, axis=0), 0).astype(tokens.dtype)
    buf = jnp.zeros((num_experts * capacity, tokens.shape[-1]), tokens.dtype)
    return buf.at[safe].add(src, mode="drop")


def combine_gather(expert_out: jax.Array, plan: DispatchPlan) -> jax.Array:
    """(E·C, d) → (S, d): inverse layout transform + weighted combine."""
    S, K = plan.slot.shape
    keep = plan.slot >= 0
    safe = jnp.where(keep, plan.slot, 0)
    gathered = expert_out[safe.reshape(S * K)].reshape(S, K, -1)
    w = (plan.weight * keep).astype(expert_out.dtype)
    return jnp.einsum("skd,sk->sd", gathered, w)


def dispatch_dense(tokens: jax.Array, plan: DispatchPlan,
                   num_experts: int, capacity: int) -> jax.Array:
    """Dense one-hot einsum dispatch — the DeepSpeed/GShard baseline the
    paper's Fig. 4 compares against.  O(S·E·C·d)."""
    S, K = plan.slot.shape
    keep = plan.slot >= 0
    mask = jax.nn.one_hot(jnp.where(keep, plan.slot, -1),
                          num_experts * capacity, dtype=tokens.dtype)  # (S,K,EC)
    return jnp.einsum("skc,sd->cd", mask, tokens)


def combine_dense(expert_out: jax.Array, plan: DispatchPlan,
                  num_experts: int, capacity: int) -> jax.Array:
    """Dense combine: (S,K,E·C) weighted one-hot × (E·C, d)."""
    keep = plan.slot >= 0
    mask = jax.nn.one_hot(jnp.where(keep, plan.slot, -1),
                          num_experts * capacity, dtype=expert_out.dtype)
    w = (plan.weight * keep).astype(expert_out.dtype)
    return jnp.einsum("skc,sk,cd->sd", mask, w, expert_out)
