"""Data layout transform (paper §3.2, Fig. 4) — and its inverse.

After the gate decides token→expert, tokens bound for the same expert
must land in physically-contiguous memory before the AllToAll.  Three
dispatch modes, selected by ``MoEConfig.dispatch``:

``sort``    HetuMoE's approach — ONE stable sort over expert ids yields
            the position-within-expert; the plan carries the sort
            permutation, per-expert counts, group offsets and the
            buffer-side inverse row map so dispatch, combine, the Pallas
            layout kernel and the aux-loss load metrics all reuse it
            instead of re-deriving routing state.  Produces the
            capacity-padded ``(E·C, d)`` buffer; tokens past capacity
            drop.  Cost: O(S·K·log(S·K)) index work + O(E·C·d) movement.
``dense``   GShard/DeepSpeed baseline — position via cumsum of one-hots
            and a (S·K, E·C) one-hot einsum.  O(S·E·C·d) FLOPs — the gap
            the paper's layout kernel exploits.
``grouped`` MegaBlocks-style dropless mode — the same single sort packs
            tokens into a contiguous ``(S·K, d)`` buffer with NO capacity
            padding and NO drops; the expert FFN runs as grouped/ragged
            matmuls over the per-expert segments (``lax.ragged_dot`` or
            the Pallas grouped kernel, kernels/grouped_ffn.py).  Cost:
            O(S·K·log(S·K)) + O(S·K·d) movement + exactly Σ_e n_e FFN
            rows — no padding FLOPs at low load, no drops at high load.
            Under expert parallelism (model_size M > 1) the grouped
            AllToAll takes over: per-expert counts cross the ``model``
            axis first, then each destination rank's expert-sorted rows
            packed to a STATIC per-rank segment bound B
            (:func:`repro.core.capacity.grouped_segment_bound`); the
            receive side rebuilds expert-major offsets from the counts
            and runs the same ragged matmuls (:class:`GroupedEPPlan`,
            :func:`plan_grouped_ep`, :func:`grouped_ep_receive_maps`).
            Under expert TENSOR parallelism the bounded chunks and their
            counts are additionally all-gathered over the TP axis and
            the same offset arithmetic merges them into one expert-major
            order every TP rank agrees on (:func:`grouped_tp_gather_maps`)
            — each rank then runs the ragged matmuls over its f-slice of
            the expert weights and a psum_scatter returns the reduced
            token rows.

Cost model (per device, S tokens, K slots, E experts, capacity C,
M expert-parallel ranks, segment bound B):

    ==========  ============================  =========================
    mode        index work                    data movement / FLOPs
    ==========  ============================  =========================
    sort        1 stable sort (S·K)           E·C·d rows moved
    dense       K cumsums over (S, E)         S·E·C·d MAC einsum
    grouped     1 stable sort (S·K)           S·K·d rows moved,
                                              Σ n_e ragged FFN rows
    grouped-EP  1 stable sort (S·K)           2·M·E/M ints (counts) +
                + O(M·B) map arithmetic       2·M·B·d rows exchanged
                                              (vs sort-EP's 2·E·C·d),
                                              Σ n_e ragged FFN rows
    grouped-TP  no extra sort (reuses the     all-gather R·B·d rows +
    (R ranks)   per-rank chunks); O(R·M·B)    R·M·E/M count ints, psum-
                map arithmetic off the        scatter R·B·d back; FFN is
                gathered count matrix         Σ_r Σ_e n_e^(r) rows ×
                                              the f/R weight slice —
                                              R× rows · 1/R width = the
                                              unsharded FLOP total
    grouped     none (reuses the fwd          dlhs: grouped matmul with
    (backward)  offsets — NO fwd recompute)   rhsᵀ over Σ n_e rows;
                                              drhs: Σ_e ceil(n_e/bm)
                                              (K, N)-tile outer-product
                                              accumulations in f32
    grouped-EP  no extra sort; O(P·N·E/M)     SAME total bytes, in P
    overlap     window-clip arithmetic off    (M, B/P, d) windows: the
    (P chunks)  the bounded count matrix      steady-state exchange hides
                (:func:`grouped_chunk_counts` behind the Σ n_e/P-row
                + the per-chunk receive maps  matmuls of the previous
                at bound B/P)                 window; only the FILL
                                              (first dispatch a2a) and
                                              DRAIN (last combine a2a)
                                              stay exposed, at P× the α
                                              message count — see
                                              ``alltoall.cost_pipelined``
    grouped-EP  SAME maps — the per-chunk     wire bytes ÷ itemsize
    quantized   amax scales ride the count    (bf16 → int8/fp8 halves
    (payload    exchange as a bitcast int32   the β term); + M f32
    dtype)      column (dispatch) or one      scales per window; dequant
                tiny (M,) flat a2a (combine)  to the compute dtype
                                              happens INSIDE the
                                              exchange, so every map
                                              above is reused unchanged
                                              (``alltoall.quantized_
                                              grouped_all_to_all``)
    ==========  ============================  =========================

The grouped-EP exchange pads to the segment bound B instead of the
per-expert capacity E·C: with the default fully-dropless B = S·K the
buffer is M·S·K rows; with a bound factor f it is f·S·K rows total —
independent of E, so wide-expert layers (E ≫ M) exchange far less than
the capacity-padded path while still never padding the FFN itself.

For ``sort``/``dense``, dropped tokens (position ≥ capacity) get
``slot = -1`` and weight 0: the residual connection carries them
unchanged (Switch semantics).  ``grouped`` never drops on one device;
under EP it drops only when one destination rank's demand exceeds the
segment bound (impossible at the default bound).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.gating import GateOutput


class DispatchPlan(NamedTuple):
    """Static-shape routing plan for S tokens × K slots.

    Token view (always present):
      ``slot``    (S, K) int32 — row in the (E·C, d) dispatch buffer, -1 dropped
      ``weight``  (S, K) f32   — combine weight, zeroed for dropped slots

    Sort-once state (from :func:`plan_sort`; ``None`` on the cumsum path):
      ``sort_order``  (S·K,)  int32 — stable argsort of k-major expert ids
      ``counts``      (E,)    int32 — per-expert assignment counts (pre-capacity)
      ``offsets``     (E+1,)  int32 — exclusive prefix sum of ``counts``
      ``inv``         (E·C,)  int32 — buffer row → source token row, -1 empty
    """
    slot: jax.Array
    weight: jax.Array
    sort_order: Optional[jax.Array] = None
    counts: Optional[jax.Array] = None
    offsets: Optional[jax.Array] = None
    inv: Optional[jax.Array] = None


class GroupedPlan(NamedTuple):
    """Dropless routing plan: S·K assignment rows sorted by expert.

    ``sort_order`` (S·K,) int32 — k-major flat slot index per sorted row
    ``token``      (S·K,) int32 — source token row per sorted row
    ``weight``     (S·K,) f32   — combine weight per sorted row
    ``counts``     (E,)   int32 — rows per expert (Σ ≤ S·K; the remainder
                                  is the virtual drop bucket's tail)
    ``offsets``    (E+1,) int32 — exclusive prefix sum of ``counts``
    """
    sort_order: jax.Array
    token: jax.Array
    weight: jax.Array
    counts: jax.Array
    offsets: jax.Array


class GroupedEPPlan(NamedTuple):
    """Send-side state for the grouped expert-parallel AllToAll.

    Built from a :class:`GroupedPlan` whose expert-sorted buffer is, by
    construction, destination-RANK-sorted too (experts shard contiguously
    over ranks): rank m's rows are the segment
    ``offsets[m·E_local] : offsets[(m+1)·E_local]``.  The plan freezes
    that ragged structure into the static ``(M, B, d)`` exchange layout
    (B the segment bound, a Python int):

    ``bound``       int            — B, rows per destination-rank chunk
    ``send_counts`` (M, E_local) int32 — rows PACKED per (dest rank,
                    local expert); differs from the raw routing counts
                    only when the bound truncates a rank's segment
    ``pack_map``    (M·B,) int32   — exchange slot → source TOKEN row
                    (-1 = padding), composing the sort gather with the
                    per-rank packing so dispatch is ONE row gather
    ``back_map``    (S·K,) int32   — sorted assignment row → exchange
                    slot (-1 = bound-dropped or virtual-bucket row), the
                    return path's gather map
    """
    bound: int
    send_counts: jax.Array
    pack_map: jax.Array
    back_map: jax.Array


def _offsets(counts: jax.Array) -> jax.Array:
    z = jnp.zeros((1,), counts.dtype)
    return jnp.concatenate([z, jnp.cumsum(counts)])


def _sort_by_expert(gate: GateOutput, n_buckets: int):
    """THE one stable sort both sort-path and grouped planning share.

    Returns ``(flat_e, order, sorted_e, counts)``: the k-major flattened
    expert ids (slot-major priority — every token's 1st choice outranks
    any 2nd choice), their stable argsort, the sorted ids, and the
    per-bucket counts.  Any change to key or priority semantics here
    changes every dispatch mode together.
    """
    S, K = gate.expert_index.shape
    flat_e = gate.expert_index.T.reshape(K * S)        # k-major flatten
    order = jnp.argsort(flat_e, stable=True)
    counts = jax.ops.segment_sum(
        jnp.ones_like(flat_e), flat_e, num_segments=n_buckets)
    return flat_e, order, flat_e[order], counts


# ---------------------------------------------------------------------------
# plan construction — position-within-expert under capacity
# ---------------------------------------------------------------------------

def plan_sort(gate: GateOutput, num_experts: int, capacity: int,
              drop_bucket: bool = False) -> DispatchPlan:
    """HetuMoE path: ONE stable argsort over expert ids.

    The stable sort keyed on expert id orders each expert's tokens by
    flattened (slot, token) index — slot-major priority (GShard/Switch
    semantics: every token's 1st choice outranks any 2nd choice) — so the
    first C stay, the rest drop.  Identical slots to :func:`plan_cumsum`.

    ``drop_bucket``: routing may use a virtual expert id == num_experts
    for padded tokens; it sorts last and is always dropped (its rows never
    reach the buffer, the counts, or the inverse map).

    Everything derived from the sort — permutation, per-expert counts,
    group offsets, and the buffer-side inverse row map — rides along in
    the plan so downstream consumers don't re-sort.
    """
    S, K = gate.expert_index.shape
    E = num_experts
    n_buckets = E + 1 if drop_bucket else E
    flat_e, order, sorted_e, counts = _sort_by_expert(gate, n_buckets)
    starts = jnp.concatenate(
        [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    pos_sorted = jnp.arange(K * S, dtype=flat_e.dtype) - starts[sorted_e]
    keep_sorted = (pos_sorted < capacity) & (sorted_e < E)
    # buffer-side inverse: buffer row e·C+p ← source token (sorted row's
    # flat index mod S); the SAME sort the token-side slots come from.
    dest = jnp.where(keep_sorted, sorted_e * capacity + pos_sorted,
                     E * capacity)
    inv = jnp.full((E * capacity,), -1, jnp.int32)
    inv = inv.at[dest].set((order % S).astype(jnp.int32), mode="drop")
    # token-side view
    pos = jnp.zeros_like(flat_e).at[order].set(pos_sorted)
    keep = jnp.zeros((K * S,), bool).at[order].set(keep_sorted)
    slot = jnp.where(keep, flat_e * capacity + pos, -1).reshape(K, S).T
    weight = jnp.where(keep.reshape(K, S).T, gate.combine_weights, 0.0)
    return DispatchPlan(slot.astype(jnp.int32), weight,
                        sort_order=order.astype(jnp.int32),
                        counts=counts[:E].astype(jnp.int32),
                        offsets=_offsets(counts[:E]).astype(jnp.int32),
                        inv=inv)


def plan_cumsum(gate: GateOutput, num_experts: int, capacity: int,
                drop_bucket: bool = False) -> DispatchPlan:
    """GShard baseline path: position via running one-hot cumsums,
    slot k accounting for all tokens of slots < k.  Identical slots to
    :func:`plan_sort` (asserted in tests); carries counts/offsets (from
    the running totals) but no sort permutation."""
    S, K = gate.expert_index.shape
    E = num_experts
    n_buckets = E + 1 if drop_bucket else E
    oh = jax.nn.one_hot(gate.expert_index, n_buckets, dtype=jnp.int32)  # (S,K,B)
    pos = jnp.zeros((S, K), jnp.int32)
    running = jnp.zeros((n_buckets,), jnp.int32)
    for k in range(K):  # K is tiny (≤8) and static — unrolled
        csum = jnp.cumsum(oh[:, k, :], axis=0) - oh[:, k, :]      # excl. cumsum
        pos = pos.at[:, k].set(
            jnp.sum(oh[:, k, :] * (csum + running[None, :]), axis=-1))
        running = running + jnp.sum(oh[:, k, :], axis=0)
    flat_e = gate.expert_index
    keep = (pos < capacity) & (flat_e < E)
    slot = jnp.where(keep, flat_e * capacity + pos, -1)
    weight = jnp.where(keep, gate.combine_weights, 0.0)
    counts = running[:E]
    return DispatchPlan(slot.astype(jnp.int32), weight,
                        counts=counts,
                        offsets=_offsets(counts))


def plan_grouped(gate: GateOutput, num_experts: int,
                 drop_bucket: bool = False) -> GroupedPlan:
    """Dropless plan: the same single stable sort, no capacity truncation.

    Virtual-bucket rows (``drop_bucket``, expert id == num_experts) sort
    to the tail with weight 0 — they occupy buffer rows past
    ``offsets[-1]`` which the grouped FFN never computes and the combine
    never weights in.
    """
    S, K = gate.expert_index.shape
    E = num_experts
    n_buckets = E + 1 if drop_bucket else E
    _, order, sorted_e, counts = _sort_by_expert(gate, n_buckets)
    counts = counts[:E]
    flat_w = gate.combine_weights.T.reshape(K * S)
    weight = jnp.where(sorted_e < E, flat_w[order], 0.0)
    return GroupedPlan(sort_order=order.astype(jnp.int32),
                       token=(order % S).astype(jnp.int32),
                       weight=weight,
                       counts=counts.astype(jnp.int32),
                       offsets=_offsets(counts).astype(jnp.int32))


def plan_grouped_ep(gplan: GroupedPlan, num_experts: int, model_size: int,
                    bound: int) -> GroupedEPPlan:
    """Freeze a :class:`GroupedPlan` into the static grouped-EP exchange
    layout (see :class:`GroupedEPPlan`).  ``bound`` must be a Python int
    (:func:`repro.core.capacity.grouped_segment_bound`)."""
    E, M, B = num_experts, model_size, bound
    assert E % M == 0, (E, M)
    E_local = E // M
    TK = gplan.token.shape[0]
    # rank boundaries in the expert-sorted buffer; bounds[M] = offsets[E]
    # excludes the virtual drop bucket's tail
    bounds = gplan.offsets[jnp.arange(M + 1) * E_local]            # (M+1,)
    rank_start = bounds[:-1]
    # per-(rank, expert) offsets RELATIVE to the rank segment, clipped at
    # the bound: truncation cuts the segment's tail (later experts first)
    g_off = gplan.offsets[jnp.arange(M)[:, None] * E_local
                          + jnp.arange(E_local + 1)[None, :]]      # (M, El+1)
    rel = jnp.minimum(g_off - rank_start[:, None], B)
    send_counts = (rel[:, 1:] - rel[:, :-1]).astype(jnp.int32)
    sent = rel[:, -1]                                              # (M,) ≤ B
    # pack: slot (m, j) ← sorted row rank_start[m]+j, straight to tokens
    j = jnp.arange(B)
    rows = rank_start[:, None] + j[None, :]                        # (M, B)
    tok = gplan.token[jnp.clip(rows, 0, max(TK - 1, 0))]
    pack_map = jnp.where(j[None, :] < sent[:, None], tok, -1)
    # back: sorted row r → its exchange slot (searchsorted-by-comparison;
    # M is small and this handles empty ranks' duplicate boundaries)
    r = jnp.arange(TK)
    m_of = jnp.sum(r[:, None] >= bounds[None, 1:], axis=-1)        # 0..M
    m_safe = jnp.clip(m_of, 0, M - 1)
    jj = r - bounds[m_safe]
    ok = (m_of < M) & (jj < B)
    back_map = jnp.where(ok, m_safe * B + jj, -1)
    return GroupedEPPlan(bound=B, send_counts=send_counts,
                         pack_map=pack_map.reshape(M * B).astype(jnp.int32),
                         back_map=back_map.astype(jnp.int32))


def grouped_ep_receive_maps(recv_counts: jax.Array, bound: int):
    """Rebuild local offsets from the exchanged counts (receive side).

    ``recv_counts`` (M, E_local) source-major — rows rank m sent here per
    local expert; ``bound`` the static B.  The received ``(M·B, d)``
    buffer is source-major / expert-sorted WITHIN each source chunk; the
    grouped FFN wants expert-major across sources.  Returns

      ``ffn_src``     (M·B,) int32 — FFN row → received-buffer row (-1
                      past the real rows: those FFN rows read zeros and
                      sit beyond ``group_sizes.sum()``, which the ragged
                      matmuls never touch)
      ``dst_map``     (M·B,) int32 — received-buffer row → FFN row (-1
                      = padding slot); the return path gathers the FFN
                      output back into exchange layout with it
      ``group_sizes`` (E_local,) int32 — FFN rows per local expert

    Pure offset arithmetic off the count matrix — no sort: destination
    row = expert base + rows from earlier source ranks + rank-local rank.
    """
    M, E_local = recv_counts.shape
    B = bound
    src_off = jnp.concatenate(
        [jnp.zeros((M, 1), jnp.int32),
         jnp.cumsum(recv_counts, axis=1, dtype=jnp.int32)], axis=1)
    chunk_tot = src_off[:, -1]                                     # (M,) ≤ B
    j = jnp.arange(B)
    # local expert of slot (m, j): how many segment ends are ≤ j
    e_id = jnp.sum(j[None, :, None] >= src_off[:, None, 1:], axis=-1)
    e_safe = jnp.clip(e_id, 0, E_local - 1)
    group_sizes = jnp.sum(recv_counts, axis=0, dtype=jnp.int32)    # (El,)
    e_base = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(group_sizes)[:-1]])
    from_prev = (jnp.cumsum(recv_counts, axis=0, dtype=jnp.int32)
                 - recv_counts)                                    # (M, El)
    dst = (e_base[e_safe]
           + jnp.take_along_axis(from_prev, e_safe, axis=1)
           + (j[None, :] - jnp.take_along_axis(src_off, e_safe, axis=1)))
    dst = jnp.where(j[None, :] < chunk_tot[:, None], dst, -1)
    dst_map = dst.reshape(M * B).astype(jnp.int32)
    # invert: FFN row → received row (valid dst values are distinct)
    ffn_src = jnp.full((M * B,), -1, jnp.int32)
    ffn_src = ffn_src.at[jnp.where(dst_map >= 0, dst_map, M * B)].set(
        jnp.arange(M * B, dtype=jnp.int32), mode="drop")
    return ffn_src, dst_map, group_sizes


def grouped_tp_gather_maps(counts: jax.Array, bound: int):
    """Expert-TP twin of :func:`grouped_ep_receive_maps`.

    The grouped expert-TP path all-gathers each TP rank's bounded
    expert-sorted buffer (single-rank: the ``(T·K, d)`` sorted buffer
    itself; under grouped-EP: the received ``(M·B, d)`` exchange
    layout) plus its per-chunk count matrix.  ``counts`` therefore
    arrives as ``(R, E)`` or ``(R, M, E_local)`` — R the TP degree —
    and every chunk of the gathered buffer satisfies the receive-map
    contract already: expert-sorted within the chunk, live rows packed
    from row 0, at most ``bound`` of them.  Flattening the leading dims
    to ``(R·M, E_local)`` source chunks makes the SAME offset
    arithmetic rebuild the expert-major FFN order across TP ranks — no
    new sort, no new collective beyond the gather itself.

    Every TP rank computes these maps from the identical gathered count
    matrix, so all ranks agree on the segment structure and each can run
    its f-slice of the grouped matmuls over the same row order (the
    f-contraction is then reduced by the caller's ``psum_scatter``).
    """
    return grouped_ep_receive_maps(
        counts.reshape(-1, counts.shape[-1]), bound)


def grouped_chunk_counts(counts: jax.Array, bound: int,
                         n_chunks: int) -> jax.Array:
    """Split bounded expert-sorted segment counts into per-window counts
    for the overlapped (chunked) grouped pipeline.

    ``counts`` ``(N, E_seg)``: row n describes an expert-sorted segment
    whose live rows are packed from row 0 of an ``(N, bound, d)`` buffer
    — the grouped-EP send layout (N = M destination ranks,
    ``GroupedEPPlan.send_counts``) or the single-rank sorted buffer
    (N = 1, the routing counts).  Returns ``(n_chunks, N, E_seg)``:
    entry p is the count matrix of window rows
    ``[p·bound/n_chunks, (p+1)·bound/n_chunks)``.

    Each window again satisfies the receive-map contract — expert-sorted
    within the window (a contiguous slice of a sorted segment stays
    sorted), live rows packed from window row 0 (the live prefix of the
    segment either covers the window start or ended before it), at most
    ``bound/n_chunks`` of them — so the SAME offset arithmetic
    (:func:`grouped_ep_receive_maps` / :func:`grouped_tp_gather_maps`
    at the per-chunk bound) rebuilds each window's expert-major FFN
    order, and the windows sum back to the unchunked counts exactly.
    """
    N, _ = counts.shape
    bc = bound // n_chunks
    off = jnp.concatenate(
        [jnp.zeros((N, 1), jnp.int32),
         jnp.cumsum(counts, axis=1, dtype=jnp.int32)], axis=1)  # (N, Es+1)
    win = (jnp.arange(n_chunks, dtype=jnp.int32) * bc)[:, None, None]
    rel = jnp.clip(off[None] - win, 0, bc)              # (P, N, Es+1)
    return (rel[..., 1:] - rel[..., :-1]).astype(jnp.int32)


# ---------------------------------------------------------------------------
# dispatch / combine execution
# ---------------------------------------------------------------------------

def dispatch_scatter(tokens: jax.Array, plan: DispatchPlan,
                     num_experts: int, capacity: int) -> jax.Array:
    """(S, d) → (E·C, d) (paper's layout-transform direction).

    With sort-once state in the plan this is a pure gather off the
    carried inverse row map (what the Pallas kernel executes on TPU);
    plans without it (cumsum path) fall back to the token-side scatter.
    """
    if plan.inv is not None:
        keep = plan.inv >= 0
        safe = jnp.where(keep, plan.inv, 0)
        return jnp.where(keep[:, None], tokens[safe], 0).astype(tokens.dtype)
    S, K = plan.slot.shape
    keep = plan.slot >= 0
    safe = jnp.where(keep, plan.slot, 0).reshape(S * K)
    src = jnp.where(keep.reshape(S * K, 1),
                    jnp.repeat(tokens, K, axis=0), 0).astype(tokens.dtype)
    buf = jnp.zeros((num_experts * capacity, tokens.shape[-1]), tokens.dtype)
    return buf.at[safe].add(src, mode="drop")


def combine_gather(expert_out: jax.Array, plan: DispatchPlan) -> jax.Array:
    """(E·C, d) → (S, d): inverse layout transform + weighted combine."""
    S, K = plan.slot.shape
    keep = plan.slot >= 0
    safe = jnp.where(keep, plan.slot, 0)
    gathered = expert_out[safe.reshape(S * K)].reshape(S, K, -1)
    w = (plan.weight * keep).astype(expert_out.dtype)
    return jnp.einsum("skd,sk->sd", gathered, w)


def dispatch_grouped(tokens: jax.Array, plan: GroupedPlan) -> jax.Array:
    """(S, d) → (S·K, d) expert-sorted buffer — no padding, no drops."""
    return tokens[plan.token]


def take_rows(src: jax.Array, idx: jax.Array) -> jax.Array:
    """out[i] = src[idx[i]], zeros where idx < 0 — the jnp twin of the
    blocked Pallas ``gather_rows`` kernel, for maps carrying -1 padding
    (grouped-EP pack/unpack)."""
    return jnp.where(idx[:, None] >= 0, src[jnp.maximum(idx, 0)], 0)


def combine_grouped(expert_out: jax.Array, plan: GroupedPlan,
                    num_tokens: int) -> jax.Array:
    """(S·K, d) expert-sorted FFN output → (S, d) weighted combine.

    The scatter-add reduction runs in f32 regardless of the buffer dtype
    (one rounding at the end, not one per addend) — the low-precision
    payload path depends on this: a bf16/int8-era combine that also
    accumulated in half precision would stack quantization error on top
    of summation error."""
    w = plan.weight.astype(jnp.float32)
    out = jnp.zeros((num_tokens, expert_out.shape[-1]), jnp.float32)
    out = out.at[plan.token].add(expert_out.astype(jnp.float32)
                                 * w[:, None])
    return out.astype(expert_out.dtype)


def dispatch_dense(tokens: jax.Array, plan: DispatchPlan,
                   num_experts: int, capacity: int) -> jax.Array:
    """Dense one-hot einsum dispatch — the DeepSpeed/GShard baseline the
    paper's Fig. 4 compares against.  O(S·E·C·d)."""
    S, K = plan.slot.shape
    keep = plan.slot >= 0
    mask = jax.nn.one_hot(jnp.where(keep, plan.slot, -1),
                          num_experts * capacity, dtype=tokens.dtype)  # (S,K,EC)
    return jnp.einsum("skc,sd->cd", mask, tokens)


def combine_dense(expert_out: jax.Array, plan: DispatchPlan,
                  num_experts: int, capacity: int) -> jax.Array:
    """Dense combine: (S,K,E·C) weighted one-hot × (E·C, d)."""
    keep = plan.slot >= 0
    mask = jax.nn.one_hot(jnp.where(keep, plan.slot, -1),
                          num_experts * capacity, dtype=expert_out.dtype)
    w = (plan.weight * keep).astype(expert_out.dtype)
    return jnp.einsum("skc,sk,cd->sd", mask, w, expert_out)
