"""Auto-tuned dispatch plans: resolve the grouped-path ``"auto"`` knobs
from the α–β cost model instead of hand-set config constants.

HetuMoE's headline wins come from *choosing* the communication strategy
per workload — hierarchical vs flat AllToAll, message aggregation,
batch-size-dependent crossovers (paper Figs. 5–8) — and the serving
stack compiles many distinct ``(cfg, mesh, shape)`` cells per process,
each of which deserves its own choice.  This module turns the
``MoEConfig`` sentinels (:data:`repro.core.config.AUTO` on ``a2a``,
``overlap_chunks``, ``grouped_block_m``, ``grouped_ep_bound_factor``,
``payload_dtype``)
into a frozen :class:`TunedPlan` per ``(cfg, mesh factoring, static
token count, dtype)`` cell, scored with the existing α–β cost functions
(``alltoall.cost_flat`` / ``cost_hierarchical`` / ``cost_pipelined``)
over a selectable fabric (a named ``LinkSpec`` pair from
``alltoall.FABRICS``, or a measure-once startup calibration persisted
to ``TUNE_moe.json``).

Contract (the reason resolution lives at choke points, not call sites):

* **Explicit values are honored verbatim.**  A config with no ``"auto"``
  knob passes through :func:`resolve_moe_config` as the SAME object —
  zero behaviour change, bitwise-identical graphs.
* **Resolution is deterministic** given (config, static shape, fabric):
  pure integer/float arithmetic, no RNG, no wall clock.  The same cell
  always resolves to the same plan, so ``"auto"`` never changes a traced
  graph shape mid-process and the serving step cache keys stay stable
  (``engine.trace_counts`` shows no new retraces).
* **The tuner never changes numerics.**  ``grouped_ep_bound_factor``
  resolves to ``None`` (truly dropless): a lossy bound drops tokens,
  which is a quality decision the user must make explicitly.

Choke points: ``moe.sharded_moe_apply`` / ``moe.validate_dispatch_config``
resolve at trace time (the per-shard token count is static there);
``serving/engine.py`` resolves at step-BUILD time so the resolved knobs
join the compiled-step cache key; ``launch/train.py`` / ``launch/serve.py``
select the mode and fabric via ``--tune auto|off|calibrate`` and
``--fabric`` (``launch/mesh.parse_fabric``).
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Dict, Optional, Tuple

from repro.core import alltoall, capacity
from repro.core.alltoall import LinkSpec
from repro.core.config import AUTO, MoEConfig

# knobs the resolver owns (a2a_inner rides along with a2a)
TUNED_KNOBS = ("a2a", "overlap_chunks", "grouped_block_m",
               "grouped_ep_bound_factor", "payload_dtype")
TUNE_MODES = ("auto", "off", "calibrate")

# payload_dtype="auto" quantizes the wire to int8 only when the α–β
# model predicts the exchange gets at least this much relatively
# cheaper.  Small (α-dominated) payloads never clear it — quantize/
# dequantize work plus the scales exchange would not pay for itself —
# and fp8 is never auto-picked: it is cheaper than int8 nowhere (same
# 1-byte wire) and strictly less accurate, so it stays an explicit
# opt-in for hardware with native fp8 convert paths.
QUANT_MIN_SAVING = 0.15

# overlap_chunks candidate ladder (filtered to divisors of the bound)
OVERLAP_LADDER = (1, 2, 4, 8)

# nominal compute throughput used to estimate the expert-FFN time the
# overlap pipeline can hide (v5e-class bf16 peak; only the RATIO of
# compute to exchange time matters, and both scale with the same d)
NOMINAL_FLOPS = 2.0e14

# measure-once calibration artifact (machine-local, not committed)
TUNE_SCHEMA = "tune_moe/v1"
TUNE_PATH = pathlib.Path(__file__).resolve().parents[3] / "TUNE_moe.json"


def has_auto_knobs(cfg: MoEConfig) -> bool:
    """True iff any tuner-owned knob carries the ``"auto"`` sentinel."""
    return any(getattr(cfg, k) == AUTO for k in TUNED_KNOBS)


@dataclasses.dataclass(frozen=True)
class TunedPlan:
    """One resolved cell: the concrete knob values plus the cost-model
    evidence they were chosen on (for benchmarks/lint reporting).  Costs
    are α–β seconds for ONE dispatch-exchange at the cell's payload;
    ``cost_serial`` / ``cost_overlapped`` are the full
    dispatch+compute+combine layer times at P=1 and the chosen P."""
    a2a: str
    a2a_inner: int
    overlap_chunks: int
    grouped_block_m: Optional[int]
    grouped_ep_bound_factor: Optional[float]
    payload_dtype: Optional[str]
    fabric: str
    payload_bytes: int
    cost_flat: float
    cost_chosen: float
    cost_serial: float
    cost_overlapped: float


# ---------------------------------------------------------------------------
# process-wide tuning state (set from the CLI; tests save/restore)
# ---------------------------------------------------------------------------

_MODE: str = "auto"
_FABRIC: Tuple[str, Tuple[LinkSpec, LinkSpec]] = (
    "ici_dcn", alltoall.FABRICS["ici_dcn"])

# (cfg, statics, mode, fabric) → TunedPlan / resolved MoEConfig.  Keys
# hash frozen dataclasses; the mode+fabric components make a CLI change
# a clean cache split, never a stale hit.
_PLAN_CACHE: Dict[tuple, TunedPlan] = {}
_CFG_CACHE: Dict[tuple, MoEConfig] = {}


def set_tuning(mode: Optional[str] = None, fabric=None):
    """Set the process tuning mode and/or default fabric.  Returns the
    previous ``(mode, fabric)`` pair so tests can restore it.

    ``fabric`` is ``(name, (fast, slow))`` — the :func:`parse_fabric`
    return shape — or a bare name from ``alltoall.FABRICS``."""
    global _MODE, _FABRIC
    prev = (_MODE, _FABRIC)
    if mode is not None:
        if mode not in ("auto", "off"):
            raise ValueError(
                f"tuning mode must be 'auto' or 'off' (calibrate is a CLI "
                f"action, not a steady state), got {mode!r}")
        _MODE = mode
    if fabric is not None:
        _FABRIC = _coerce_fabric(fabric)
    return prev


def get_tuning() -> Tuple[str, Tuple[str, Tuple[LinkSpec, LinkSpec]]]:
    return _MODE, _FABRIC


def clear_plan_cache() -> None:
    _PLAN_CACHE.clear()
    _CFG_CACHE.clear()


def _coerce_fabric(fabric) -> Tuple[str, Tuple[LinkSpec, LinkSpec]]:
    if isinstance(fabric, str):
        if fabric not in alltoall.FABRICS:
            raise ValueError(
                f"unknown fabric {fabric!r}; valid fabrics: "
                f"{tuple(alltoall.FABRICS)}")
        return fabric, alltoall.FABRICS[fabric]
    name, pair = fabric
    fast, slow = pair
    return str(name), (fast, slow)


# ---------------------------------------------------------------------------
# the resolver
# ---------------------------------------------------------------------------

def _dtype_bytes(dtype) -> int:
    """Itemsize of the compute dtype the payload is exchanged at.

    ``None`` is an error, not a default: silently assuming bf16 (2
    bytes) mis-scored an f32 run's flat-vs-hierarchical payload by 2×.
    The choke points (``moe.sharded_moe_apply``, the serving step
    builders) always know the concrete activation dtype — they must
    pass it."""
    if dtype is None:
        raise ValueError(
            "_dtype_bytes(None): plan resolution needs the concrete "
            "activation dtype (payload bytes scale α–β costs); pass "
            "dtype=x.dtype at the choke point instead of relying on a "
            "bf16 guess")
    import numpy as np
    try:
        return int(np.dtype(dtype).itemsize)
    except TypeError:
        import jax.numpy as jnp
        return int(jnp.dtype(dtype).itemsize)


def _round_up(n: int, align: int = 8) -> int:
    return -(-n // align) * align


def _ffn_seconds(cfg: MoEConfig, rows: int, d_model: int) -> float:
    """Rough expert-FFN time for ``rows`` dispatched rows: 3 matmuls
    (gate/up/out) of d×f each, 2 FLOPs per MAC, at NOMINAL_FLOPS.  Only
    its magnitude relative to the α–β exchange time matters — both are
    coarse models of the same hardware generation."""
    f = cfg.d_ff_expert or 4 * d_model
    return rows * d_model * f * 3 * 2 / NOMINAL_FLOPS


def _factoring(model_size: int, inner: int) -> Tuple[int, int]:
    """(N, G) nodes × GPUs for the α–β functions: G = the fast inner
    group, N = the slow outer dimension."""
    if 1 < inner < model_size and model_size % inner == 0:
        return model_size // inner, inner
    return model_size, 1


def resolve_plan(cfg: MoEConfig, *, model_size: int, tokens_per_shard: int,
                 d_model: int, dtype=None, fabric=None) -> TunedPlan:
    """Resolve one ``(cfg, model_size, tokens_per_shard, d_model, dtype)``
    cell into a frozen :class:`TunedPlan`.  Deterministic and cached;
    given a concrete ``dtype`` it never raises for a valid config (the
    knobs it emits always pass ``moe.validate_dispatch_config``).

    Auto payload policy: ``payload_dtype="auto"`` resolves to
    ``"int8"`` iff the α–β model predicts the 1-byte wire makes the
    flat dispatch exchange at least :data:`QUANT_MIN_SAVING` relatively
    cheaper than at the compute dtype, else ``None`` (lossless).  fp8
    is explicit-only — see the QUANT_MIN_SAVING note."""
    mode, default_fab = get_tuning()
    fab_name, (fast, slow) = (_coerce_fabric(fabric) if fabric is not None
                              else default_fab)
    isz = _dtype_bytes(dtype)
    key = (cfg, model_size, tokens_per_shard, d_model, isz, mode,
           fab_name, fast, slow)
    plan = _PLAN_CACHE.get(key)
    if plan is not None:
        return plan

    # knob 1 — grouped_ep_bound_factor: AUTO → None (never lossy).
    factor = (None if cfg.grouped_ep_bound_factor == AUTO
              else cfg.grouped_ep_bound_factor)
    base = dataclasses.replace(
        cfg, grouped_ep_bound_factor=factor, a2a="flat", a2a_inner=1,
        overlap_chunks=1, grouped_block_m=None)

    T = int(tokens_per_shard)
    grouped = cfg.dispatch == "grouped"
    ep = grouped and model_size > 1
    if ep:
        B = capacity.grouped_segment_bound(base, T, model_size)
        buffer_rows = model_size * B
        payload = model_size * B * d_model * isz
    elif grouped:
        B = capacity.grouped_tp_gather_bound(base, T)
        buffer_rows = B
        payload = 0                  # TP gather, no EP exchange to tune
    else:
        E = cfg.num_experts
        C = capacity.expert_capacity(base, T, E)
        B = 0
        buffer_rows = E * C
        payload = (E * C * d_model * isz) if model_size > 1 else 0

    # knob 0 — payload_dtype: only the grouped-EP exchange quantizes;
    # everywhere else (TP gather, dense dispatch, model_size == 1) AUTO
    # resolves to None.  In auto mode, quantize iff the predicted
    # relative saving of the 1-byte wire clears QUANT_MIN_SAVING —
    # α-dominated (small) payloads stay lossless.
    qdt = None if cfg.payload_dtype == AUTO else cfg.payload_dtype
    if cfg.payload_dtype == AUTO and mode != "off" and ep and payload:
        full_c = alltoall.cost_flat(payload, model_size, 1, fast, slow)
        quant_c = alltoall.cost_flat(payload // isz, model_size, 1,
                                     fast, slow)
        if full_c > 0 and (full_c - quant_c) / full_c >= QUANT_MIN_SAVING:
            qdt = "int8"
    if qdt is not None and ep:
        payload = payload // isz     # every wire dtype is 1 byte

    if mode == "off":
        # pre-refactor defaults, no cost model consulted
        plan = TunedPlan(a2a="flat", a2a_inner=1, overlap_chunks=1,
                         grouped_block_m=None, grouped_ep_bound_factor=factor,
                         payload_dtype=qdt,
                         fabric=fab_name, payload_bytes=payload,
                         cost_flat=0.0, cost_chosen=0.0,
                         cost_serial=0.0, cost_overlapped=0.0)
        _PLAN_CACHE[key] = plan
        return plan

    # knob 2 — a2a mode (+ inner): for every two-stage factoring of the
    # model axis, score flat AND hierarchical at the SAME (N, G) — the
    # paper's Fig. 7 comparison, where the fast inner fabric is a mesh
    # property both strategies see.  Hierarchical wins only when
    # strictly cheaper at its best factoring (ties go flat — fewer
    # collectives, same cost).
    flat_cost = (alltoall.cost_flat(payload, model_size, 1, fast, slow)
                 if payload else 0.0)
    a2a_mode, a2a_inner = "flat", 1
    chosen_cost = flat_cost
    if cfg.a2a == AUTO:
        if payload:
            best = None                  # (hier_cost, flat_at_same_NG, inner)
            for inner in range(2, model_size):
                if model_size % inner:
                    continue
                N, G = model_size // inner, inner
                hc = alltoall.cost_hierarchical(payload, N, G, fast, slow)
                if best is None or hc < best[0]:
                    best = (hc, alltoall.cost_flat(payload, N, G, fast,
                                                   slow), inner)
            if best is not None:
                flat_cost = best[1]
                if best[0] < flat_cost:
                    a2a_mode, a2a_inner = "hierarchical", best[2]
                    chosen_cost = best[0]
                else:
                    chosen_cost = flat_cost
    else:
        a2a_mode, a2a_inner = cfg.a2a, cfg.a2a_inner
        N, G = _factoring(model_size, a2a_inner if a2a_mode == "hierarchical"
                          else 1)
        if payload:
            flat_cost = alltoall.cost_flat(payload, N, G, fast, slow)
            chosen_cost = (alltoall.cost_hierarchical(payload, N, G, fast,
                                                      slow)
                           if G > 1 else flat_cost)
    N, G = _factoring(model_size, a2a_inner if a2a_mode == "hierarchical"
                      else 1)
    cost_fn = (alltoall.cost_hierarchical if G > 1 else alltoall.cost_flat)

    # knob 3 — overlap_chunks: divisor ladder, argmin of the pipelined
    # layer time (2× exchange + FFN, fill/drain exposed) — only the
    # grouped-EP path has an exchange to hide.
    ffn_s = _ffn_seconds(cfg, buffer_rows, d_model) if grouped else 0.0
    serial = 2 * chosen_cost + ffn_s

    def pipe_cost(P: int) -> float:
        if P <= 1:
            return serial
        return alltoall.cost_pipelined(payload, N, G, fast, slow,
                                       n_chunks=P, compute_s=ffn_s,
                                       cost_fn=cost_fn)

    overlap = 1
    if cfg.overlap_chunks == AUTO:
        if ep and payload:
            best = serial
            for P in OVERLAP_LADDER:
                if P > 1 and B % P == 0 and pipe_cost(P) < best:
                    overlap, best = P, pipe_cost(P)
    else:
        overlap = cfg.overlap_chunks
    overlapped = pipe_cost(overlap)

    # knob 4 — grouped_block_m: clamp the kernel row block to the
    # per-window buffer (sublane-aligned) so tiny decode windows stop
    # padding to a full default block.
    if cfg.grouped_block_m == AUTO:
        if grouped:
            from repro.kernels.grouped_ffn import DEFAULT_BLOCK_M
            window_rows = buffer_rows // max(overlap, 1)
            block_m = max(8, min(DEFAULT_BLOCK_M, _round_up(window_rows)))
        else:
            block_m = None
    else:
        block_m = cfg.grouped_block_m

    plan = TunedPlan(a2a=a2a_mode, a2a_inner=a2a_inner,
                     overlap_chunks=overlap, grouped_block_m=block_m,
                     grouped_ep_bound_factor=factor,
                     payload_dtype=qdt, fabric=fab_name,
                     payload_bytes=payload, cost_flat=flat_cost,
                     cost_chosen=chosen_cost, cost_serial=serial,
                     cost_overlapped=overlapped)
    _PLAN_CACHE[key] = plan
    return plan


def apply_plan(cfg: MoEConfig, plan: TunedPlan) -> MoEConfig:
    """The concrete config: plan values fill ONLY the ``"auto"`` fields
    (explicit values are honored verbatim)."""
    kw = {}
    if cfg.a2a == AUTO:
        kw["a2a"] = plan.a2a
        kw["a2a_inner"] = plan.a2a_inner
    if cfg.overlap_chunks == AUTO:
        kw["overlap_chunks"] = plan.overlap_chunks
    if cfg.grouped_block_m == AUTO:
        kw["grouped_block_m"] = plan.grouped_block_m
    if cfg.grouped_ep_bound_factor == AUTO:
        kw["grouped_ep_bound_factor"] = plan.grouped_ep_bound_factor
    if cfg.payload_dtype == AUTO:
        kw["payload_dtype"] = plan.payload_dtype
    return dataclasses.replace(cfg, **kw) if kw else cfg


def resolve_moe_config(cfg: MoEConfig, *, model_size: int,
                       tokens_per_shard: int, d_model: int,
                       dtype=None, fabric=None) -> MoEConfig:
    """``cfg`` with every ``"auto"`` knob resolved for this cell.  A
    config with no autos is returned as the SAME object (bitwise
    pass-through); resolved configs are memoized so repeated step builds
    hand the cache identical keys."""
    if not has_auto_knobs(cfg):
        return cfg
    mode, (fab_name, _) = get_tuning()
    key = (cfg, model_size, int(tokens_per_shard), int(d_model),
           _dtype_bytes(dtype), mode, fab_name, fabric)
    out = _CFG_CACHE.get(key)
    if out is None:
        plan = resolve_plan(cfg, model_size=model_size,
                            tokens_per_shard=tokens_per_shard,
                            d_model=d_model, dtype=dtype, fabric=fabric)
        out = apply_plan(cfg, plan)
        _CFG_CACHE[key] = out
    return out


def describe_resolution(auto_cfg: MoEConfig, resolved: MoEConfig) -> str:
    """Human-readable "what did 'auto' become" — appended to validation
    errors so they name the RESOLVED values, not the sentinel."""
    parts = []
    if auto_cfg.a2a == AUTO:
        parts.append(f"a2a={resolved.a2a!r} (a2a_inner="
                     f"{resolved.a2a_inner})")
    if auto_cfg.overlap_chunks == AUTO:
        parts.append(f"overlap_chunks={resolved.overlap_chunks}")
    if auto_cfg.grouped_block_m == AUTO:
        parts.append(f"grouped_block_m={resolved.grouped_block_m}")
    if auto_cfg.grouped_ep_bound_factor == AUTO:
        parts.append(
            f"grouped_ep_bound_factor={resolved.grouped_ep_bound_factor}")
    if auto_cfg.payload_dtype == AUTO:
        parts.append(f"payload_dtype={resolved.payload_dtype!r}")
    return "auto-tuned: resolved " + ", ".join(parts) if parts else ""


# ---------------------------------------------------------------------------
# measure-once startup calibration (--tune calibrate)
# ---------------------------------------------------------------------------

def fit_alpha_beta(points) -> LinkSpec:
    """Least-squares fit of ``time = α + β·bytes`` over ``(bytes, s)``
    samples, clamped positive (a throttled box can fit a negative slope
    on two noisy points; the cost functions need monotone specs)."""
    import numpy as np
    pts = [(float(b), float(t)) for b, t in points]
    if len(pts) < 2:
        raise ValueError(
            f"fit_alpha_beta needs >= 2 (bytes, seconds) samples, got "
            f"{len(pts)}")
    b = np.array([p[0] for p in pts])
    t = np.array([p[1] for p in pts])
    A = np.stack([np.ones_like(b), b], axis=1)
    (alpha, beta), *_ = np.linalg.lstsq(A, t, rcond=None)
    return LinkSpec(alpha=float(max(alpha, 1e-9)),
                    beta=float(max(beta, 1e-15)))


def _measure_a2a(mesh, axis_name: str, rows: int, d: int, *,
                 iters: int = 5) -> float:
    """Median wall seconds of one jitted flat AllToAll of (M·rows, d)
    f32 over ``axis_name``."""
    import time as _time

    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.core.compat import shard_map

    M = mesh.shape[axis_name]
    x = jnp.zeros((M * rows, d), jnp.float32)
    fn = jax.jit(shard_map(
        lambda v: alltoall.flat_all_to_all(v, axis_name), mesh=mesh,
        in_specs=P(axis_name), out_specs=P(axis_name), check_vma=False))
    jax.block_until_ready(fn(x))
    times = []
    for _ in range(iters):
        t0 = _time.perf_counter()
        jax.block_until_ready(fn(x))
        times.append(_time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def save_calibration(path, fast: LinkSpec, slow: LinkSpec,
                     points=None) -> None:
    doc = {"schema": TUNE_SCHEMA,
           "fast": {"alpha": fast.alpha, "beta": fast.beta},
           "slow": {"alpha": slow.alpha, "beta": slow.beta},
           "points": [[float(b), float(t)] for b, t in (points or [])]}
    pathlib.Path(path).write_text(json.dumps(doc, indent=2) + "\n")


def load_calibration(path=None):
    """``("calibrated", (fast, slow))`` from a TUNE_moe.json, or ``None``
    when the file is missing, unreadable, schema-mismatched, or carries
    non-positive constants — every failure mode falls back to the static
    ``alltoall.FABRICS`` table, never raises."""
    p = pathlib.Path(path) if path is not None else TUNE_PATH
    try:
        doc = json.loads(p.read_text())
        if doc.get("schema") != TUNE_SCHEMA:
            return None
        specs = []
        for level in ("fast", "slow"):
            alpha = float(doc[level]["alpha"])
            beta = float(doc[level]["beta"])
            if alpha <= 0 or beta <= 0:
                return None
            specs.append(LinkSpec(alpha=alpha, beta=beta))
        return "calibrated", (specs[0], specs[1])
    except (OSError, ValueError, KeyError, TypeError):
        return None


def calibrate_fabric(mesh=None, *, axis_name: str = "model", path=None,
                     remeasure: bool = False):
    """Measure-once α–β calibration: reuse an intact ``TUNE_moe.json``
    when present, else benchmark a handful of flat-AllToAll payloads on
    ``mesh`` and fit.  On a single-fabric host (this container's fake
    CPU devices, or a mesh with no ``axis_name``) the one measured level
    serves as both fast and slow — strategy crossovers then come only
    from message counts, which is the honest statement of what was
    measurable.  Returns ``(name, (fast, slow))`` and persists it."""
    p = pathlib.Path(path) if path is not None else TUNE_PATH
    if not remeasure:
        loaded = load_calibration(p)
        if loaded is not None:
            return loaded
    if mesh is None or mesh.shape.get(axis_name, 1) <= 1:
        # nothing to exchange across — persist the static default so the
        # artifact's provenance is explicit
        fast, slow = get_tuning()[1][1]
        save_calibration(p, fast, slow)
        return "calibrated", (fast, slow)
    d = 128
    points = [(mesh.shape[axis_name] * rows * d * 4,
               _measure_a2a(mesh, axis_name, rows, d))
              for rows in (8, 64, 512)]
    spec = fit_alpha_beta(points)
    save_calibration(p, spec, spec, points)
    return "calibrated", (spec, spec)


def configure(mode: str = "auto", fabric=None, *, mesh=None,
              path=None) -> Tuple[str, str]:
    """CLI entry for ``--tune``/``--fabric`` (train.py / serve.py).
    Returns ``(mode, fabric_name)`` for the launcher's banner."""
    if mode not in TUNE_MODES:
        raise ValueError(
            f"--tune must be one of {TUNE_MODES}, got {mode!r}")
    if mode == "off":
        set_tuning(mode="off", fabric=fabric)
        return "off", get_tuning()[1][0]
    if mode == "calibrate":
        fab = calibrate_fabric(mesh, path=path)
    else:
        fab = fabric
    set_tuning(mode="auto", fabric=fab)
    return mode, get_tuning()[1][0]
