"""Deterministic fault injection for the fault-tolerance layer.

Production MoE training lives with preemptions, flaky NICs, and
numerically unstable bf16 steps; every guard in this repo (skip-step,
crash-safe checkpointing, serving overload degradation) must therefore be
*provable under test*.  This module provides the single source of
injected faults: seeded, config-addressable fault **sites** — named seams
instrumented in the production code paths — that fire deterministically
at chosen step/uid indices, never randomly at run time.

A :class:`FaultPlan` maps site names to :class:`FaultSpec`\\ s.  Sites in
use today:

====================================  =======================================
site                                  seam (who calls it, with what index)
====================================  =======================================
``train.activations``                 traced: hidden states before the CE
                                      loss (``make_train_step``, step)
``train.loss``                        traced: the scalar loss (step)
``train.grads``                       traced: every grad leaf after the
                                      (possibly accumulated) backward (step)
``train.loop``                        host: top of the driver step loop
                                      (``launch/train.py``, step) — ``raise``
                                      / ``kill`` simulate preemption
``ckpt.data_tmp_written``             host: checkpoint tmp file written +
                                      fsynced, before ``os.replace`` (step)
``ckpt.data_replaced``                host: ``.npz`` in place, manifests not
                                      yet written (step)
``ckpt.manifest_step_written``        host: per-step manifest written,
                                      ``manifest.json`` not yet updated (step)
``serve.prefill``                     host: before a request's prefill
                                      (``SlotServer``, request uid) —
                                      ``raise`` = prefill blows up
``serve.prefill_logits``              host: the request's prefill logits
                                      (uid) — ``nan``/``inf`` = poisoned
``serve.step_logits``                 host: one slot's decode logits (uid)
``serve.step``                        host: before each batched decode step
                                      (decode-step counter) — ``stall``
                                      simulates a step-time stall
``serve.decode_row``                  host: the batched decode logits as
                                      returned by the step-builder's
                                      compiled step
                                      (``serving/engine.build_decode``,
                                      decode-step counter) — ``nan``/``inf``
                                      poisons ONE seeded element, i.e. one
                                      slot's decode row
====================================  =======================================

Two delivery mechanisms:

* **Traced** (:func:`traced_factor`): returns a scalar that is ``1.0``
  except at the spec'd step values, where it is NaN/Inf — multiplied into
  tensors *inside* jit, so the injection point is fixed at trace time and
  the firing step is data-dependent (``jnp.isin`` on the step counter).
* **Host** (:func:`crash_point`, :func:`inject_array`,
  :func:`maybe_stall`): consult the *ambient* plan installed with
  :func:`active`; no-ops when no plan is active, so the seams cost
  nothing in production.

File-corruption helpers (:func:`corrupt_file`) are plain deterministic
utilities — tests call them directly on checkpoint files to exercise the
fallback-restore path.
"""
from __future__ import annotations

import contextlib
import os
import signal
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


class FaultInjected(RuntimeError):
    """Raised by a ``raise``-mode fault site (simulated crash/poison)."""


@dataclass(frozen=True)
class FaultSpec:
    """When and how one fault site fires.

    ``steps`` are the index values (train step, request uid, decode-step
    counter — whatever the seam passes) at which the site fires;
    ``always=True`` fires at every index.  ``mode``:

    * ``nan`` / ``inf`` — poison the value at the seam
    * ``raise``         — raise :class:`FaultInjected` (in-process crash)
    * ``kill``          — SIGKILL the process (real crash; subprocess tests)
    * ``stall``         — sleep ``stall_s`` seconds (simulated slow step)
    """
    steps: Tuple[int, ...] = ()
    mode: str = "nan"
    always: bool = False
    stall_s: float = 0.05

    MODES = ("nan", "inf", "raise", "kill", "stall")

    def __post_init__(self):
        if self.mode not in self.MODES:
            raise ValueError(f"FaultSpec.mode={self.mode!r} not in {self.MODES}")


@dataclass
class FaultPlan:
    """A seeded set of fault sites.  ``fired`` records (site, index) hits
    so tests can assert a guard was actually exercised."""
    sites: Dict[str, FaultSpec] = field(default_factory=dict)
    seed: int = 0
    fired: List[Tuple[str, int]] = field(default_factory=list)

    def spec(self, site: str) -> Optional[FaultSpec]:
        return self.sites.get(site)

    def fires(self, site: str, index: int = 0) -> Optional[FaultSpec]:
        """The spec if ``site`` fires at ``index`` (recording the hit)."""
        sp = self.sites.get(site)
        if sp is None or not (sp.always or index in sp.steps):
            return None
        self.fired.append((site, index))
        return sp


def plan_from_specs(specs: Sequence[str], seed: int = 0) -> FaultPlan:
    """Parse CLI-style fault specs: ``site:mode@step[,step...]`` (or
    ``site:mode@*`` for every index), e.g.
    ``train.grads:nan@3`` or ``ckpt.data_tmp_written:kill@20``."""
    sites: Dict[str, FaultSpec] = {}
    for raw in specs:
        try:
            site, rest = raw.split(":", 1)
            mode, at = rest.split("@", 1)
        except ValueError:
            raise ValueError(
                f"fault spec {raw!r} is not 'site:mode@steps' "
                f"(e.g. 'train.grads:nan@3' or 'serve.step:stall@*')")
        if at.strip() == "*":
            sites[site] = FaultSpec(mode=mode, always=True)
        else:
            steps = tuple(int(s) for s in at.split(",") if s.strip())
            sites[site] = FaultSpec(steps=steps, mode=mode)
    return FaultPlan(sites=sites, seed=seed)


# ---------------------------------------------------------------------------
# ambient (host-side) plan
# ---------------------------------------------------------------------------

_ACTIVE: Optional[FaultPlan] = None


def get_active() -> Optional[FaultPlan]:
    return _ACTIVE


@contextlib.contextmanager
def active(plan: Optional[FaultPlan]):
    """Install ``plan`` as the ambient plan for host-side seams."""
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, plan
    try:
        yield plan
    finally:
        _ACTIVE = prev


def crash_point(site: str, index: int = 0) -> None:
    """Host seam: raise / SIGKILL here if the ambient plan says so."""
    plan = _ACTIVE
    sp = plan.fires(site, index) if plan is not None else None
    if sp is None:
        return
    if sp.mode == "raise":
        raise FaultInjected(f"injected crash at {site}[{index}]")
    if sp.mode == "kill":
        os.kill(os.getpid(), signal.SIGKILL)


def maybe_stall(site: str, index: int = 0) -> None:
    """Host seam: sleep if a ``stall`` fault fires (simulated slow step)."""
    plan = _ACTIVE
    sp = plan.fires(site, index) if plan is not None else None
    if sp is not None and sp.mode == "stall":
        time.sleep(sp.stall_s)


def inject_array(site: str, x, index: int = 0) -> np.ndarray:
    """Host seam: return ``x`` (as numpy) with one seeded element poisoned
    if the ambient plan fires ``site`` at ``index``; else ``x`` unchanged."""
    plan = _ACTIVE
    arr = np.asarray(x)
    sp = plan.fires(site, index) if plan is not None else None
    if sp is None or sp.mode not in ("nan", "inf"):
        return arr
    out = np.array(arr, copy=True)
    flat = out.reshape(-1)
    rng = np.random.default_rng((plan.seed, abs(hash(site)) % 2**31, index))
    pos = int(rng.integers(flat.size)) if flat.size else 0
    if flat.size:
        flat[pos] = np.nan if sp.mode == "nan" else np.inf
    return out


# ---------------------------------------------------------------------------
# traced (jit-side) injection
# ---------------------------------------------------------------------------

def traced_factor(plan: Optional[FaultPlan], site: str, step):
    """A scalar multiplier for use INSIDE jit: 1.0 except at the spec'd
    step values, where it is NaN (``nan`` mode) or Inf (``inf``).  Returns
    None when the site is absent so callers can skip the multiply (keeps
    un-faulted graphs bitwise identical)."""
    if plan is None:
        return None
    sp = plan.sites.get(site)
    if sp is None or sp.mode not in ("nan", "inf"):
        return None
    import jax.numpy as jnp
    bad = jnp.float32(jnp.nan if sp.mode == "nan" else jnp.inf)
    if sp.always:
        return bad
    if not sp.steps:
        return None
    fire = jnp.isin(jnp.asarray(step, jnp.int32),
                    jnp.asarray(sp.steps, jnp.int32))
    return jnp.where(fire, bad, jnp.float32(1.0))


def apply_traced(plan: Optional[FaultPlan], site: str, step, tree):
    """Multiply every leaf of ``tree`` by :func:`traced_factor` (no-op —
    and no inserted ops — when the site is absent)."""
    f = traced_factor(plan, site, step)
    if f is None:
        return tree
    import jax
    return jax.tree.map(lambda x: x * f.astype(x.dtype), tree)


# ---------------------------------------------------------------------------
# file corruption (checkpoint fault utilities)
# ---------------------------------------------------------------------------

def corrupt_file(path: str, *, mode: str = "truncate", seed: int = 0,
                 nbytes: int = 16) -> None:
    """Deterministically damage a file in place.  ``truncate`` cuts it to
    half size (a torn write); ``bitflip`` XOR-flips ``nbytes`` seeded
    bytes (bit rot / bad NIC DMA)."""
    size = os.path.getsize(path)
    if mode == "truncate":
        with open(path, "r+b") as f:
            f.truncate(max(size // 2, 1))
        return
    if mode == "bitflip":
        rng = np.random.default_rng((seed, size))
        with open(path, "r+b") as f:
            for off in rng.integers(0, max(size, 1), size=nbytes):
                f.seek(int(off))
                b = f.read(1)
                if not b:
                    continue
                f.seek(int(off))
                f.write(bytes([b[0] ^ 0xFF]))
        return
    raise ValueError(f"corrupt_file mode={mode!r} not in ('truncate', 'bitflip')")
