"""Flat and hierarchical AllToAll (paper §3.2, Figs. 5–7) on a named mesh axis.

The paper's setting: N nodes × G GPUs, one NIC per node.  A flat NCCL
AllToAll moves B/(N·G)-byte messages — latency-bound on the slow link.
HetuMoE instead (1) aggregates intra-node over the fast fabric, (2)
layout-transforms so each node's outbound data is contiguous per
destination node, (3) runs the inter-node AllToAll with G²×-aggregated
messages.

TPU adaptation (DESIGN.md §2): the expert-parallel mesh axis is factored
``model = outer × inner``.  ``inner`` spans the fast/contiguous ICI
dimension (the "intra-node" fabric); ``outer`` crosses the slower
dimension (long ICI hop or pod/DCN boundary).  Stage 1 is an AllToAll
inside ``inner`` groups, a transpose (the layout transform — free in
registers on TPU, a real kernel on GPU), then stage 2 inside ``outer``
groups with inner×-aggregated messages.

Both paths are FUNCTIONALLY IDENTICAL (asserted in tests); the win is in
message count/size, captured by the α–β cost model below and in the
roofline's collective term.

Chunk convention: input ``(M, c, …)`` destination-major (chunk i → axis
index i); output ``(M, c, …)`` source-major — the convention of
``lax.all_to_all(tiled=True)``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.config import A2A_MODES


def flat_all_to_all(x: jax.Array, axis_name: str) -> jax.Array:
    """Vanilla AllToAll over the full named axis (NCCL-equivalent)."""
    return lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0, tiled=True)


def _inner_groups(outer: int, inner: int) -> Sequence[Sequence[int]]:
    """Groups of consecutive ranks — one per 'node'."""
    return [[o * inner + i for i in range(inner)] for o in range(outer)]


def _outer_groups(outer: int, inner: int) -> Sequence[Sequence[int]]:
    """Strided groups — rank i of every node."""
    return [[o * inner + i for o in range(outer)] for i in range(inner)]


def hierarchical_all_to_all(x: jax.Array, axis_name: str, *,
                            inner: int, outer: int) -> jax.Array:
    """Two-stage AllToAll over axis of size ``outer·inner``.

    Device rank r = o·inner + i.  Stage A exchanges over the destination
    inner index within each node (fast fabric); after the transpose each
    device holds, contiguously per destination node, everything its node
    sends there; stage B crosses nodes with inner×-larger messages.
    """
    M = outer * inner
    c = x.shape[1:]
    assert x.shape[0] == M, (x.shape, M)
    # [dest_o, dest_i] destination-major chunks
    x = x.reshape(outer, inner, *c)
    x = jnp.swapaxes(x, 0, 1)                      # [dest_i, dest_o]
    # Stage A — intra-node: exchange the dest-inner dimension.
    x = lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0, tiled=True,
                       axis_index_groups=_inner_groups(outer, inner))
    # now [src_i, dest_o]: everything MY NODE sends to (dest_o, my_i)
    x = jnp.swapaxes(x, 0, 1)                      # [dest_o, src_i] — the
    # layout transform: per-destination-node data is now contiguous.
    # Stage B — inter-node: inner×-aggregated messages.
    x = lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0, tiled=True,
                       axis_index_groups=_outer_groups(outer, inner))
    # now [src_o, src_i] — source-major, same convention as flat.
    return x.reshape(M, *c)


def all_to_all(x: jax.Array, axis_name: str, *, mode: str = "flat",
               inner: int = 1, outer: Optional[int] = None) -> jax.Array:
    """Mode-dispatching entry point used by the MoE layer.

    ``mode="hierarchical"`` requires ``inner`` to divide the axis size
    exactly: a silent floor (``outer = M // inner``) would either quietly
    run flat (inner > M) or trip an opaque reshape assert deep inside the
    ``shard_map`` trace (outer·inner != M).  Validated up front instead.

    An unknown ``mode`` is a config error and raises whatever ``inner``
    is — previously it silently ran flat when ``inner <= 1`` and died on
    a bare ``assert`` otherwise.  ``inner < 1`` is likewise a config
    error: a typo'd ``a2a_inner=0`` (or negative) used to silently
    disable the paper's hierarchical win by falling back to flat.
    ``inner == 1`` remains the documented degenerate-flat case (every
    'node' is a single rank, so the two-stage exchange IS the flat one).
    """
    if mode not in A2A_MODES:
        raise ValueError(
            f"all_to_all: unknown mode {mode!r} (MoEConfig.a2a); valid "
            f"modes: {A2A_MODES}")
    if inner < 1:
        raise ValueError(
            f"all_to_all: inner={inner} (MoEConfig.a2a_inner) must be "
            f">= 1 — 1 degenerates to the flat exchange; 0 or negative "
            f"would silently disable the hierarchical path")
    if mode == "flat" or inner == 1:
        return flat_all_to_all(x, axis_name)
    M = x.shape[0]
    if M % inner != 0:
        raise ValueError(
            f"hierarchical AllToAll: axis {axis_name!r} has size {M} "
            f"(the expert-parallel model_size), which inner={inner} "
            f"(MoEConfig.a2a_inner) does not divide — pick a2a_inner "
            f"from the divisors of {M}, or use a2a='flat'")
    if outer is None:
        outer = M // inner
    if outer * inner != M:
        raise ValueError(
            f"hierarchical AllToAll: outer={outer} · inner={inner} != "
            f"axis size {M} (axis {axis_name!r})")
    if outer <= 1:
        return flat_all_to_all(x, axis_name)
    return hierarchical_all_to_all(x, axis_name, inner=inner, outer=outer)


def grouped_all_to_all(tokens: jax.Array, counts: jax.Array,
                       axis_name: str, *, mode: str = "flat",
                       inner: int = 1):
    """Grouped-EP exchange: bounded token segments plus their counts.

    ``tokens`` ``(M, B, d)`` destination-major — chunk m holds the first
    ``counts[m].sum()`` rows this rank sends to rank m (expert-sorted, B
    the static segment bound); ``counts`` ``(M, E_local)`` destination-
    major per-(rank, local-expert) row counts.  Returns the source-major
    pair ``(recv_tokens, recv_counts)``: chunk m of each is what rank m
    sent here.  The token payload rides the flat OR hierarchical
    collective (the paper's two-stage win applies unchanged — segments
    are opaque (B, d) chunks); the tiny count matrix always goes flat,
    since its bytes are noise next to its latency.

    Chunked (overlapped) exchange: the pipelined grouped path
    (``MoEConfig.overlap_chunks = P > 1``) calls this once per
    ``(M, B/P, d)`` WINDOW of the bounded segment, with the matching
    per-window count matrix (``layout.grouped_chunk_counts``).  Nothing
    here changes — each window is a self-contained grouped exchange at
    the per-chunk bound, and the received windows reassemble to the
    (M, B, d) layout by concatenation along the bound dim.  The cost
    trade is modeled by :func:`cost_pipelined`.
    """
    recv_counts = lax.all_to_all(counts, axis_name, split_axis=0,
                                 concat_axis=0, tiled=True)
    recv_tokens = all_to_all(tokens, axis_name, mode=mode, inner=inner)
    return recv_tokens, recv_counts


# ---------------------------------------------------------------------------
# Quantized exchange payloads (MegaScale-MoE): the dispatch/combine token
# buffers tolerate far lower precision than compute, so the wire moves
# int8/fp8 with one f32 amax scale per (source-rank chunk, overlap
# window), and the receive side dequantizes into the f32-accumulating
# grouped matmuls.  β shrinks by the itemsize ratio; the α terms and the
# count exchange are unchanged (one extra tiny flat scales exchange in
# the combine direction — see moe.expected_grouped_a2a_eqns).
# ---------------------------------------------------------------------------

# Largest representable magnitude per wire dtype: the amax of a chunk
# maps onto this, so quantization saturates exactly at the chunk max.
# int8 uses the symmetric [-127, 127] grid (−128 stays unused, keeping
# the grid sign-symmetric); the fp8 values are jnp.finfo(dt).max.
PAYLOAD_QMAX = {
    "int8": 127.0,
    "float8_e4m3fn": 448.0,
    "float8_e5m2": 57344.0,
}


def _payload_jnp_dtype(payload_dtype: str):
    if payload_dtype not in PAYLOAD_QMAX:
        raise ValueError(
            f"unknown payload dtype {payload_dtype!r} "
            f"(MoEConfig.payload_dtype); valid: {sorted(PAYLOAD_QMAX)}")
    return jnp.dtype(payload_dtype)


def quantize_payload(x: jax.Array, payload_dtype: str):
    """Per-chunk symmetric quantization of ``(M, …)`` payloads.

    One f32 amax scale per leading-axis chunk (the per-destination-rank
    segment of one overlap window): ``q = round(x / s)`` on the int8
    grid, or a scaled cast for the fp8 dtypes.  Returns ``(q, scales)``
    with ``scales`` shaped ``(M,)``; all-zero chunks get scale 1 so the
    round trip stays exact.  Scale arithmetic is f32 regardless of the
    compute dtype.
    """
    dt = _payload_jnp_dtype(payload_dtype)
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=tuple(range(1, x.ndim)))
    scales = jnp.where(amax > 0, amax / PAYLOAD_QMAX[payload_dtype], 1.0)
    y = xf / scales.reshape(scales.shape + (1,) * (x.ndim - 1))
    if payload_dtype == "int8":
        q = jnp.clip(jnp.round(y), -127.0, 127.0).astype(dt)
    else:
        q = y.astype(dt)
    return q, scales


def dequantize_payload(q: jax.Array, scales: jax.Array, dtype) -> jax.Array:
    """Inverse of :func:`quantize_payload`: widen to f32, apply the
    per-chunk scale, then cast to ``dtype`` in one place — the cast
    form the ``dtype-leak`` lint rule expects (never hand a dot a wire-
    dtype operand)."""
    s = scales.reshape(scales.shape + (1,) * (q.ndim - 1))
    return (q.astype(jnp.float32) * s).astype(dtype)


def quantized_grouped_all_to_all(tokens: jax.Array,
                                 counts: Optional[jax.Array],
                                 axis_name: str, *, mode: str = "flat",
                                 inner: int = 1, payload_dtype: str):
    """Quantized variant of :func:`grouped_all_to_all`.

    The ``(M, B, d)`` token window is quantized per source chunk and
    crosses the mesh at ``payload_dtype``; the per-chunk f32 scales ride
    ALONGSIDE the count matrix — bitcast to an extra int32 column of the
    (already flat) counts exchange, so the dispatch direction emits
    exactly the same number of collectives as the unquantized path.
    With ``counts=None`` (the combine direction, which has no count
    matrix) the scales go over their own tiny flat exchange instead.

    Returns source-major ``(recv_tokens, recv_counts, recv_scales)``
    with ``recv_tokens`` still at the wire dtype — the caller (normally
    :func:`quantized_exchange`) dequantizes with ``recv_scales``.
    """
    q, scales = quantize_payload(tokens, payload_dtype)
    if counts is not None:
        packed = jnp.concatenate(
            [counts.astype(jnp.int32),
             lax.bitcast_convert_type(scales, jnp.int32)[:, None]], axis=1)
        r = lax.all_to_all(packed, axis_name, split_axis=0, concat_axis=0,
                           tiled=True)
        recv_counts = r[:, :-1].astype(counts.dtype)
        recv_scales = lax.bitcast_convert_type(r[:, -1], jnp.float32)
    else:
        recv_counts = None
        recv_scales = lax.all_to_all(scales, axis_name, split_axis=0,
                                     concat_axis=0, tiled=True)
    recv_tokens = all_to_all(q, axis_name, mode=mode, inner=inner)
    return recv_tokens, recv_counts, recv_scales


@partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6))
def _quantized_exchange(tokens, counts, axis_name, mode, inner,
                        payload_dtype, out_dtype):
    rq, rcounts, rscales = quantized_grouped_all_to_all(
        tokens, counts, axis_name, mode=mode, inner=inner,
        payload_dtype=payload_dtype)
    recv = dequantize_payload(rq, rscales,
                              tokens.dtype if out_dtype is None else out_dtype)
    return recv, rcounts


def _quantized_exchange_fwd(tokens, counts, axis_name, mode, inner,
                            payload_dtype, out_dtype):
    out = _quantized_exchange(tokens, counts, axis_name, mode, inner,
                              payload_dtype, out_dtype)
    # residuals: a zero-size dtype carrier for the cotangent's cast, and
    # the count matrix's shape for its float0 cotangent — NOT the
    # forward activations, so the backward dequantizes off nothing but
    # the cotangent itself (no recompute).
    return out, (jnp.zeros((0,), tokens.dtype), counts)


def _quantized_exchange_bwd(axis_name, mode, inner, payload_dtype,
                            out_dtype, res, cts):
    proto, counts = res
    g, _ = cts
    # The chunk permutation of all_to_all(split=concat=0) is an
    # involution, so the transpose is the same exchange — with the
    # cotangent payload quantized the same way (MegaScale-MoE: gradient
    # payloads tolerate low precision too).  Scales are treated as
    # constants of the forward (straight-through), so no activation
    # residuals are needed.
    gq, gscales = quantize_payload(g, payload_dtype)
    rgq = all_to_all(gq, axis_name, mode=mode, inner=inner)
    rgs = lax.all_to_all(gscales, axis_name, split_axis=0, concat_axis=0,
                         tiled=True)
    gx = dequantize_payload(rgq, rgs, proto.dtype)
    if counts is None:
        return gx, None
    return gx, np.zeros(counts.shape, jax.dtypes.float0)


_quantized_exchange.defvjp(_quantized_exchange_fwd, _quantized_exchange_bwd)


def quantized_exchange(tokens: jax.Array, counts: Optional[jax.Array],
                       axis_name: str, *, mode: str = "flat",
                       inner: int = 1, payload_dtype: str,
                       out_dtype=None):
    """Differentiable quantize → AllToAll → dequantize round trip.

    Forward: :func:`quantized_grouped_all_to_all` then
    :func:`dequantize_payload` into ``out_dtype`` (default
    ``tokens.dtype``; the combine direction passes f32 so the combine
    reduction stays f32).  Backward (``custom_vjp``): the SAME quantized
    exchange applied to the cotangent — the wire stays low-precision in
    both directions, scales are straight-through constants, and nothing
    of the forward is recomputed.  Returns ``(recv, recv_counts)``;
    ``recv_counts`` is ``None`` when ``counts`` is.
    """
    return _quantized_exchange(tokens, counts, axis_name, mode, inner,
                               payload_dtype, out_dtype)


# ---------------------------------------------------------------------------
# α–β (latency–bandwidth) cost model — used by benchmarks/ and the roofline.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LinkSpec:
    """One fabric level.  alpha: per-message latency (s); beta: per-byte
    time (s/B) = 1/bandwidth."""
    alpha: float
    beta: float


# TPU v5e defaults (per chip): ICI ~50 GB/s/link; DCN much slower.
ICI = LinkSpec(alpha=1e-6, beta=1 / 50e9)
DCN = LinkSpec(alpha=50e-6, beta=1 / 6.25e9)
# Paper's commodity GPU cluster levels, for the Fig. 7 reproduction:
# PCIe intra-node; 1 NIC (~100 Gb Ethernet/RoCE) per node.  The NIC α
# includes NCCL's per-message rendezvous cost — the small-message
# inefficiency HetuMoE attacks.
PCIE = LinkSpec(alpha=5e-6, beta=1 / 12e9)
ETH100 = LinkSpec(alpha=50e-6, beta=1 / 12.5e9)

# Named (fast, slow) fabric pairs — the vocabulary of the ``--fabric``
# CLI flag (``launch/mesh.parse_fabric``) and the auto-tuner's static
# table (``core/tuning.py``; a startup calibration can replace the pair
# with measured α–β fits).  Keep keys lowercase: parse_fabric folds case.
FABRICS = {
    "ici_dcn": (ICI, DCN),          # TPU pod: ICI fast dim, DCN pod hop
    "pcie_eth100": (PCIE, ETH100),  # paper's GPU cluster (Fig. 7)
}


def cost_flat(bytes_per_device: float, N: int, G: int,
              fast: LinkSpec, slow: LinkSpec) -> float:
    """Flat AllToAll on N nodes × G GPUs, per-node NIC-centric.

    Each GPU sends M-1 = N·G-1 messages of B/M bytes.  Intra-node
    messages ride the fast fabric in parallel per GPU; the G·G·(N-1)
    inter-node messages of ONE NODE all serialize through its single NIC
    (the paper's Fig. 5 bottleneck): G² messages per node-pair.
    """
    M = N * G
    msg = bytes_per_device / M
    intra = (G - 1) * (fast.alpha + msg * fast.beta)
    n_nic_msgs = G * G * (N - 1)                     # through one NIC
    nic_bytes = G * (M - G) / M * bytes_per_device
    inter = n_nic_msgs * slow.alpha + nic_bytes * slow.beta
    return intra + inter


def cost_hierarchical(bytes_per_device: float, N: int, G: int,
                      fast: LinkSpec, slow: LinkSpec) -> float:
    """Two-stage AllToAll: same NIC bytes, but G× fewer / G× larger
    inter-node messages (paper: B/(GN) → BG/N message size).

    Stage A: intra-node AllToAll, G-1 messages of B/G per GPU (fast).
    Stage B: per node, G·(N-1) messages of B/N through the NIC.
    """
    a = (G - 1) * (fast.alpha + (bytes_per_device / G) * fast.beta)
    n_nic_msgs = G * (N - 1)
    nic_bytes = G * (N - 1) / N * bytes_per_device
    b = n_nic_msgs * slow.alpha + nic_bytes * slow.beta
    return a + b


def cost_pipelined(bytes_per_device: float, N: int, G: int,
                   fast: LinkSpec, slow: LinkSpec, *, n_chunks: int,
                   compute_s: float, cost_fn=cost_hierarchical) -> float:
    """Chunked dispatch-exchange ↔ expert-compute pipeline, α–β level.

    The serial grouped layer pays ``a2a(B) + T_ffn + a2a(B)`` (dispatch,
    matmuls, combine).  Splitting into P windows and double-buffering,
    the steady state hides the smaller of the per-window terms behind
    the larger; only the pipeline FILL (the first window's dispatch
    exchange) and DRAIN (the last window's combine) stay exposed:

        T_pipe ≈ a2a(B/P)                     fill
               + (P-1) · max(a2a(B/P), T_ffn/P)   steady state
               + T_ffn/P + a2a(B/P)           drain

    The α term is paid P× (P× more, P× smaller messages) — chunking
    spends the paper's message-aggregation win to buy latency hiding,
    so the optimum P balances ``α·P`` growth against the hidden
    ``β·B`` term.  That autotuning of ``overlap_chunks`` is the ROADMAP
    follow-up; this function is its objective.
    """
    per = cost_fn(bytes_per_device / n_chunks, N, G, fast, slow)
    per_ffn = compute_s / n_chunks
    return per + (n_chunks - 1) * max(per, per_ffn) + per_ffn + per
