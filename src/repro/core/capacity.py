"""Expert-capacity computation (GShard/Switch semantics).

Fixed per-expert capacity makes every MoE buffer static — mandatory for
XLA/TPU, and exactly the contiguous layout the paper's layout-transform
kernel produces.  Tokens beyond capacity are dropped (their combine
weight is zeroed, so the residual path carries them through).
"""
from __future__ import annotations

import math

from repro.core.config import MoEConfig
from repro.core import gating


def expert_capacity(cfg: MoEConfig, num_tokens: int, num_experts: int,
                    *, align: int = 8) -> int:
    """Per-expert token capacity for a group of ``num_tokens`` tokens.

    capacity = ceil(k · S / E · capacity_factor), rounded up to ``align``
    (sublane alignment for the (E, C, d) dispatch buffer; the d dimension
    carries the 128-lane requirement).
    """
    k = gating.gate_k(cfg)
    cap = math.ceil(num_tokens * k / num_experts * cfg.capacity_factor)
    cap = max(align, math.ceil(cap / align) * align)
    return min(cap, num_tokens * k)
