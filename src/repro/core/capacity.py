"""Expert-capacity computation (GShard/Switch semantics).

Fixed per-expert capacity makes every MoE buffer static — mandatory for
XLA/TPU, and exactly the contiguous layout the paper's layout-transform
kernel produces.  Tokens beyond capacity are dropped (their combine
weight is zeroed, so the residual path carries them through).
"""
from __future__ import annotations

import math

from repro.core.config import MoEConfig
from repro.core import gating


def _round_up(n: int, align: int) -> int:
    return math.ceil(n / align) * align


def expert_capacity(cfg: MoEConfig, num_tokens: int, num_experts: int,
                    *, align: int = 8) -> int:
    """Per-expert token capacity for a group of ``num_tokens`` tokens.

    capacity = ceil(k · S / E · capacity_factor), rounded up to ``align``
    (sublane alignment for the (E, C, d) dispatch buffer; the d dimension
    carries the 128-lane requirement).  The total-assignment clamp
    (no expert can ever see more than S·k tokens) is itself rounded up to
    ``align`` so the result ALWAYS honors the alignment contract — a raw
    ``min(cap, S·k)`` returns e.g. 4 for a T=4/K=1 decode batch.
    """
    k = gating.gate_k(cfg)
    cap = math.ceil(num_tokens * k / num_experts * cfg.capacity_factor)
    cap = max(align, _round_up(cap, align))
    return min(cap, _round_up(num_tokens * k, align))


def grouped_segment_bound(cfg: MoEConfig, num_tokens: int, model_size: int,
                          *, align: int = 8) -> int:
    """Static per-(source, destination)-rank row bound B for the grouped
    expert-parallel AllToAll (the dropless path's capacity analogue).

    XLA needs static shapes, so the exchanged ``(model_size, B, d)``
    buffer cannot size itself from the runtime counts; B comes from
    config instead:

      * ``grouped_ep_bound_factor is None`` (default) → B = T·K — a rank
        can receive every local assignment, so the exchange NEVER drops
        (truly dropless, at the cost of an M×-padded exchange buffer).
      * factor f → B = ceil(T·K/M · f) rounded up to ``align``: the
        balanced per-rank share times a capacity-factor-style headroom.
        Rows past B for one destination rank drop (zero output, residual
        carries the token — sort-path semantics).
    """
    k = gating.gate_k(cfg)
    total = num_tokens * k
    dropless = _round_up(total, align)
    f = cfg.grouped_ep_bound_factor
    if isinstance(f, str):
        # "auto" must be resolved (core/tuning.resolve_moe_config) before
        # any bound is derived — a sentinel reaching arithmetic here would
        # raise an opaque TypeError deep in a trace
        raise ValueError(
            f"grouped_segment_bound: grouped_ep_bound_factor={f!r} is "
            f"unresolved — resolve 'auto' knobs first "
            f"(core/tuning.resolve_moe_config)")
    if model_size <= 1 or f is None:
        return dropless
    b = max(align, _round_up(math.ceil(total / model_size * f), align))
    return min(b, dropless)


def grouped_tp_gather_bound(cfg: MoEConfig, num_tokens: int) -> int:
    """Static per-TP-rank row bound for the grouped expert-TP all-gather
    WITHOUT expert parallelism: B = T·K, the full expert-sorted buffer
    gathered as-is (no packing step, no padding rows beyond the
    routing's own virtual-bucket tail).

    The expert-TP path gathers every TP rank's bounded expert-sorted
    segments into one ``(R·B, d)`` buffer whose chunk boundaries all
    ranks must agree on — a rank deriving a different B would desync the
    gathered layout (rank r's rows landing where rank r+1 expects its
    own).  Agreement holds because B is a pure function of the config
    and the STATIC per-shard token count (tokens shard evenly over the
    mesh, so ``num_tokens`` is the same Python int on every TP rank).
    Under grouped-EP the TP gather operates on the EP exchange layout
    instead, so its bound IS :func:`grouped_segment_bound` — same
    agreement argument, same static inputs.
    """
    return num_tokens * gating.gate_k(cfg)


def grouped_overlap_chunk_bound(cfg: MoEConfig, bound: int) -> int:
    """Per-chunk row bound Bc = bound / overlap_chunks for the overlapped
    (chunked, double-buffered) grouped pipeline.

    Agreement across ranks: ``bound`` is already a pure function of the
    config and the STATIC per-shard token count
    (:func:`grouped_segment_bound` under expert parallelism,
    :func:`grouped_tp_gather_bound` otherwise), and ``overlap_chunks``
    is config — so every EP/TP rank derives the same Bc and the chunked
    exchange / TP-gather layouts stay aligned window for window.

    The division must be exact: a remainder window would give the final
    chunk a different static shape than the rest, and the pipeline's
    collectives (grouped AllToAll, TP all-gather) need one shape for
    every window.
    """
    chunks = cfg.overlap_chunks
    if isinstance(chunks, str):
        raise ValueError(
            f"grouped_overlap_chunk_bound: overlap_chunks={chunks!r} is "
            f"unresolved — resolve 'auto' knobs first "
            f"(core/tuning.resolve_moe_config)")
    if chunks <= 1:
        return bound
    if bound % chunks:
        raise ValueError(
            f"MoEConfig.overlap_chunks={chunks} does not divide the grouped "
            f"segment bound B={bound} (grouped_segment_bound / "
            f"grouped_tp_gather_bound at this shard's token count) — pick "
            f"overlap_chunks from the divisors of {bound}, or adjust "
            f"MoEConfig.grouped_ep_bound_factor so the bound is a multiple")
    return bound // chunks
