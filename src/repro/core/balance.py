"""Router auxiliary losses + load metrics (Switch/GShard style)."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import MoEConfig
from repro.core.gating import GateOutput


def load_balance_loss(gate: GateOutput) -> jax.Array:
    """Switch Transformer aux loss: E · Σ_e f_e · P_e.

    f_e — fraction of tokens whose FIRST choice is e (hard counts);
    P_e — mean router probability of e (soft, differentiable).
    Minimized (=1) by a uniform assignment.
    """
    E = gate.router_probs.shape[-1]
    first = gate.expert_index[:, 0]
    f = jnp.mean(jax.nn.one_hot(first, E, dtype=gate.router_probs.dtype), axis=0)
    p = jnp.mean(gate.router_probs, axis=0)
    return E * jnp.sum(f * p)


def router_z_loss(gate: GateOutput) -> jax.Array:
    """ST-MoE z-loss: mean (logsumexp logits)² — keeps router logits small."""
    return jnp.mean(jax.nn.logsumexp(gate.logits, axis=-1) ** 2)


def aux_losses(cfg: MoEConfig, gate: GateOutput,
               expert_counts: jax.Array | None = None,
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Weighted aux-loss scalar + router metrics dict.

    ``expert_counts`` (E,) — per-expert assignment counts already derived
    by the dispatch plan's single sort; passing them skips the O(S·K·E)
    one-hot re-count here (sort-once: the plan is the source of truth for
    load state).
    """
    E = gate.router_probs.shape[-1]
    lb = load_balance_loss(gate)
    zl = router_z_loss(gate)
    loss = cfg.aux_loss_weight * lb + cfg.router_z_loss_weight * zl
    if expert_counts is not None:
        counts = expert_counts.astype(jnp.float32)
    else:
        counts = jnp.sum(
            jax.nn.one_hot(gate.expert_index, E, dtype=jnp.float32), axis=(0, 1))
    metrics = {
        "load_balance_loss": lb,
        "router_z_loss": zl,
        "expert_load_max": jnp.max(counts) / jnp.maximum(jnp.sum(counts), 1.0),
        "expert_load_min": jnp.min(counts) / jnp.maximum(jnp.sum(counts), 1.0),
    }
    return loss, metrics
