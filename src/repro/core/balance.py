"""Router auxiliary losses + load metrics (Switch/GShard style)."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.config import MoEConfig
from repro.core.gating import GateOutput

# THE canonical router-metric key list.  ``aux_losses`` returns exactly
# these keys (zipped strictly against the values it computes), and
# ``moe.sharded_moe_apply`` builds its shard_map metric out_specs from
# this tuple — add a metric here (and its value in ``aux_losses``) and
# every consumer stays in sync; duplicating the names at the shard_map
# boundary produced an opaque pytree-mismatch error instead.
METRIC_KEYS = ("load_balance_loss", "router_z_loss",
               "expert_load_max", "expert_load_min")


def _masked_mean(x: jax.Array, valid: Optional[jax.Array],
                 axes: Tuple[str, ...] = ()) -> jax.Array:
    """Mean of ``x`` over its leading (token) axis, restricted to the
    ``valid`` rows.  Padded decode tokens (rerouted to the virtual
    expert, combine weight zeroed) must not bias the router statistics.

    ``axes``: mesh axis names to aggregate over (inside shard_map).  The
    (sum, count) pair is psum'd BEFORE dividing, so every valid token
    weighs the same globally — a pmean of per-shard means would
    over-weight tokens on padding-heavy shards (and count an all-padding
    shard as a zero), breaking padded ≡ unpadded.
    """
    if valid is None:
        s = jnp.sum(x, axis=0)
        n = jnp.asarray(x.shape[0], s.dtype)
    else:
        w = valid.astype(x.dtype)
        s = jnp.sum(x * (w[:, None] if x.ndim > 1 else w), axis=0)
        n = jnp.sum(w)
    if axes:
        s = lax.psum(s, axes)
        n = lax.psum(n, axes)
    return s / jnp.maximum(n, 1.0)


def load_balance_loss(gate: GateOutput,
                      valid: Optional[jax.Array] = None,
                      axes: Tuple[str, ...] = ()) -> jax.Array:
    """Switch Transformer aux loss: E · Σ_e f_e · P_e.

    f_e — fraction of tokens whose FIRST choice is e (hard counts);
    P_e — mean router probability of e (soft, differentiable).
    Minimized (=1) by a uniform assignment.  ``valid`` (S,) bool masks
    padded rows out of BOTH means (their expert_index points at the
    virtual expert, so they would deflate f_e and skew P_e otherwise);
    ``axes`` makes the means global over the mesh (see _masked_mean).
    """
    E = gate.router_probs.shape[-1]
    first = gate.expert_index[:, 0]
    f = _masked_mean(
        jax.nn.one_hot(first, E, dtype=gate.router_probs.dtype), valid, axes)
    p = _masked_mean(gate.router_probs, valid, axes)
    return E * jnp.sum(f * p)


def router_z_loss(gate: GateOutput,
                  valid: Optional[jax.Array] = None,
                  axes: Tuple[str, ...] = ()) -> jax.Array:
    """ST-MoE z-loss: mean (logsumexp logits)² — keeps router logits small.
    ``valid`` masks padded rows (their all-zero logits contribute a
    spurious log(E)² each)."""
    return _masked_mean(jax.nn.logsumexp(gate.logits, axis=-1) ** 2,
                        valid, axes)


def aux_losses(cfg: MoEConfig, gate: GateOutput,
               expert_counts: jax.Array | None = None,
               valid: Optional[jax.Array] = None,
               axes: Tuple[str, ...] = (),
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Weighted aux-loss scalar + router metrics dict.

    ``expert_counts`` (E,) — per-expert assignment counts already derived
    by the dispatch plan's single sort; passing them skips the O(S·K·E)
    one-hot re-count here (sort-once: the plan is the source of truth for
    load state).  ``valid`` (S,) — mask of real (non-padded) tokens;
    ``axes`` — mesh axes to reduce over, making lb/z-loss exact GLOBAL
    masked means (the caller's later pmean is then an identity on them).
    """
    E = gate.router_probs.shape[-1]
    lb = load_balance_loss(gate, valid, axes)
    zl = router_z_loss(gate, valid, axes)
    loss = cfg.aux_loss_weight * lb + cfg.router_z_loss_weight * zl
    if expert_counts is not None:
        counts = expert_counts.astype(jnp.float32)
    else:
        counts = jnp.sum(
            jax.nn.one_hot(gate.expert_index, E, dtype=jnp.float32), axis=(0, 1))
    total = jnp.maximum(jnp.sum(counts), 1.0)
    # zip(strict=True) raises even under ``python -O`` if a metric is
    # added to one side but not the other
    metrics = dict(zip(METRIC_KEYS,
                       (lb, zl, jnp.max(counts) / total,
                        jnp.min(counts) / total), strict=True))
    return loss, metrics
