"""Configuration dataclasses for the repro framework.

Everything downstream (model zoo, MoE layer, launcher, dry-run) is driven
by these frozen dataclasses.  One ``ModelConfig`` fully describes an
architecture; ``src/repro/configs/<id>.py`` instantiates one per assigned
architecture.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Gating strategies supported (paper Fig. 2 — HetuMoE supports all of these).
# ---------------------------------------------------------------------------
GATE_STRATEGIES = (
    "topk",            # Shazeer et al. 2017 — generic top-k
    "switch",          # Fedus et al. 2021 — top-1
    "gshard",          # Lepikhin et al. 2020 — top-2 (2nd expert sampled)
    "ktop1",           # M6-T — k prototypes, top-1 within each
    "sam",             # SAM — hierarchical: switch over groups, top-k inside
    "base",            # BASE layer — balanced linear assignment
    "hash",            # Hash layer — token-id hashing
    "dense_to_sparse", # Nie et al. 2021 — gumbel-softmax annealed density
)

# The auto-tuning sentinel: a grouped-path knob set to AUTO is resolved
# into a concrete value by ``core/tuning.py`` from the α–β cost model at
# the existing choke points (``moe.sharded_moe_apply`` at trace time,
# the serving step builders at step-BUILD time).  Explicit values are
# ALWAYS honored verbatim — the resolver never touches a knob the user
# set, so explicit-int configs behave bitwise-identically to a build
# without the tuner.
AUTO = "auto"

A2A_MODES = ("flat", "hierarchical")

# Wire dtypes the grouped AllToAll payload may be quantized to
# (MegaScale-MoE: dispatch/combine payloads tolerate far lower precision
# than compute).  Per-(source-chunk, window) amax scales travel alongside
# the count matrices (core/alltoall.py quantize_payload /
# quantized_grouped_all_to_all); the grouped matmuls still accumulate in
# f32 off the dequantized rows.
PAYLOAD_DTYPES = ("int8", "float8_e4m3fn", "float8_e5m2")
# sort    = HetuMoE layout-transform into the capacity-padded (E·C, d) buffer
# dense   = one-hot einsum baseline (GShard/DeepSpeed)
# grouped = dropless: expert-sorted (S·K, d) buffer + ragged/grouped expert
#           matmuls (MegaBlocks-style).  Under expert parallelism
#           (model_size > 1) the grouped AllToAll exchanges per-expert
#           counts then bounded token segments (core/alltoall.py,
#           core/layout.py GroupedEPPlan); under expert TP the bounded
#           chunks + counts all-gather over the TP axis and each rank
#           runs its f-slice (core/layout.py grouped_tp_gather_maps).
#           overlap_chunks > 1 pipelines the exchange against the
#           matmuls in static microchunk windows (core/moe.py).
DISPATCH_MODES = ("sort", "dense", "grouped")


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-Experts layer configuration."""
    num_experts: int
    top_k: int = 1
    gate: str = "switch"
    capacity_factor: float = 1.25
    d_ff_expert: Optional[int] = None      # expert hidden width (defaults to model d_ff)
    num_shared_experts: int = 0            # always-on experts (Llama4-style)
    num_prototypes: int = 1                # for ktop1 (M6)
    num_groups: int = 1                    # for sam hierarchical routing
    dispatch: str = "sort"                 # see DISPATCH_MODES above
    # AllToAll mode: "flat" | "hierarchical" | "auto".  "auto" scores
    # both modes (and every valid a2a_inner factoring) with the α–β cost
    # model at the shape being traced/built (core/tuning.py) and picks
    # the cheaper one; it resolves a2a_inner too, so an explicit
    # a2a_inner alongside a2a="auto" is ignored.  Explicit modes are
    # honored verbatim.
    a2a: str = "flat"
    a2a_inner: int = 4                     # inner group size for hierarchical a2a
    # Grouped-EP segment bound: per-(source, destination)-rank row budget
    # for the grouped AllToAll, as a multiple of the balanced share
    # T·K/model_size.  None → T·K (any single rank may receive every
    # assignment: truly dropless, maximal padding).  Smaller values trade
    # exchange-buffer padding for sort-style drops when one rank's demand
    # exceeds the bound.  "auto" resolves to None: the tuner never picks
    # a lossy bound, because drops change numerics — the sentinel exists
    # so presets can mark the knob tuner-owned uniformly.  See
    # capacity.grouped_segment_bound.
    grouped_ep_bound_factor: Optional[float] = None
    aux_loss_weight: float = 0.01
    router_z_loss_weight: float = 0.0
    router_dtype: str = "float32"
    gumbel_temperature: float = 1.0        # for dense_to_sparse
    # Use the Pallas kernel paths end to end: fused top-k gate, blocked
    # layout transform, and (grouped mode) the grouped-matmul FFN —
    # forward AND backward (kernels/grouped_ffn.py).  Off, the
    # equivalent jnp/ragged_dot implementations run instead.
    use_pallas_gate: bool = False
    # Row-block size for the grouped-matmul kernels (fwd, dlhs, drhs).
    # None → the kernel default (kernels/grouped_ffn.DEFAULT_BLOCK_M).
    # "auto" → min(kernel default, the per-window buffer rows rounded to
    # the sublane multiple), so tiny decode windows stop padding to a
    # full 128-row block.  Explicit ints are honored verbatim.
    grouped_block_m: Optional[int] = None
    # Overlapped (chunked) grouped pipeline: split the bounded expert-
    # sorted dispatch buffer into this many static microchunks and
    # software-pipeline the grouped AllToAll against the grouped expert
    # matmuls (core/moe.py; 1 = no pipelining, today's serial exchange).
    # Grouped dispatch only.  Must divide the grouped segment bound —
    # checked where the bound is known, since the bound depends on the
    # per-shard token count (capacity.grouped_overlap_chunk_bound).
    # "auto" → argmin of alltoall.cost_pipelined over the divisor ladder
    # {1, 2, 4, 8} ∩ divisors(bound); explicit ints are honored verbatim
    # (including ones the tuner would never pick — bound divisibility is
    # still validated, with the usual ValueError).
    overlap_chunks: int = 1
    # Wire dtype for the grouped exchange payloads (dispatch AND combine
    # directions).  None → the payload crosses the mesh at the compute
    # dtype (today's behavior, bitwise identical graphs).  A PAYLOAD_DTYPES
    # member quantizes each (source-chunk, overlap-window) payload with a
    # per-chunk amax scale before the AllToAll and dequantizes on the
    # receive side into the f32-accumulating grouped matmuls; the combine
    # reduction stays in f32 (core/alltoall.py, core/moe.py).  "auto" →
    # the α–β cost model picks the cheapest tolerance-safe wire dtype per
    # cell (core/tuning.py: int8 when the predicted payload-β saving
    # clears QUANT_MIN_SAVING, else None — see resolve_plan's policy
    # note).  Grouped dispatch only; a no-op when the exchange never
    # crosses ranks (model_size == 1).  Explicit values are honored
    # verbatim per the PR 9 tunable-knob convention.
    payload_dtype: Optional[str] = None

    def __post_init__(self):
        # real exceptions, not asserts: these must survive ``python -O``
        # (a stripped assert let a typo'd mode reach deep collective code)
        if self.gate not in GATE_STRATEGIES:
            raise ValueError(
                f"MoEConfig.gate={self.gate!r} is not a known gating "
                f"strategy; valid options: {GATE_STRATEGIES}")
        if self.a2a not in A2A_MODES + (AUTO,):
            raise ValueError(
                f"MoEConfig.a2a={self.a2a!r} is not a known AllToAll "
                f"mode; valid options: {A2A_MODES + (AUTO,)}")
        if self.dispatch not in DISPATCH_MODES:
            raise ValueError(
                f"MoEConfig.dispatch={self.dispatch!r} is not a known "
                f"dispatch mode; valid options: {DISPATCH_MODES}")
        if self.a2a_inner < 1:
            raise ValueError(
                f"MoEConfig.a2a_inner must be >= 1, got {self.a2a_inner}")
        f = self.grouped_ep_bound_factor
        if f is not None and f != AUTO and (
                not isinstance(f, (int, float)) or f <= 0):
            raise ValueError(
                f"MoEConfig.grouped_ep_bound_factor must be positive, "
                f"None, or {AUTO!r}, got {f!r}")
        bm = self.grouped_block_m
        if bm is not None and bm != AUTO and (
                not isinstance(bm, int) or bm < 1):
            raise ValueError(
                f"MoEConfig.grouped_block_m must be an int >= 1, None, or "
                f"{AUTO!r}, got {bm!r}")
        if self.overlap_chunks != AUTO and (
                not isinstance(self.overlap_chunks, int)
                or self.overlap_chunks < 1):
            raise ValueError(
                f"MoEConfig.overlap_chunks must be an int >= 1 (1 disables "
                f"the overlapped pipeline) or {AUTO!r}, got "
                f"{self.overlap_chunks!r}")
        pd = self.payload_dtype
        if pd is not None and pd != AUTO and pd not in PAYLOAD_DTYPES:
            raise ValueError(
                f"MoEConfig.payload_dtype={pd!r} is not a known exchange "
                f"wire dtype; valid options: None (compute dtype), "
                f"{PAYLOAD_DTYPES}, or {AUTO!r}")


@dataclass(frozen=True)
class AttentionConfig:
    num_heads: int
    num_kv_heads: int
    head_dim: Optional[int] = None          # default d_model // num_heads
    rope_theta: float = 10_000.0
    use_rope: bool = True
    window: Optional[int] = None            # sliding-window size (SWA layers)
    attn_softcap: Optional[float] = None    # gemma2-style attn logit softcap
    causal: bool = True
    qk_norm: bool = False


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) block configuration."""
    d_state: int = 64
    head_dim: int = 64
    expand: int = 2
    chunk_size: int = 128
    conv_width: int = 4
    n_groups: int = 1


@dataclass(frozen=True)
class RWKVConfig:
    """RWKV-6 'Finch' time-mix configuration."""
    head_dim: int = 64
    chunk_size: int = 128
    decay_lora: int = 64       # low-rank dim for data-dependent decay
    mix_lora: int = 32         # low-rank dim for token-shift interpolation


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    # Per-layer block kinds, cycled:  num_layers % len(block_pattern) == 0.
    #   attn        full (or windowed, per AttentionConfig.window) attention + MLP
    #   local       sliding-window attention + MLP (gemma2 alternation)
    #   global      full attention + MLP
    #   moe         attention + MoE FFN
    #   dense       attention + dense FFN (used in moe interleave)
    #   mamba       Mamba-2 block
    #   mamba_sa    Mamba-2 block followed by the *shared* attention block (zamba2)
    #   rwkv        RWKV-6 time-mix + channel-mix
    block_pattern: Tuple[str, ...] = ("attn",)
    attention: Optional[AttentionConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    encoder_only: bool = False
    frontend: Optional[str] = None    # None | "audio" | "vision"
    act: str = "swiglu"               # swiglu | geglu | gelu
    norm: str = "rmsnorm"
    norm_eps: float = 1e-6
    final_softcap: Optional[float] = None   # gemma2 final-logit softcap
    local_window: int = 4096          # window used by "local" blocks
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # embedding scale (gemma-style sqrt(d_model) multiplier)
    scale_embeddings: bool = False
    source: str = ""                  # citation for the config

    def __post_init__(self):
        assert self.num_layers % len(self.block_pattern) == 0, (
            f"{self.name}: num_layers={self.num_layers} not divisible by "
            f"pattern period {len(self.block_pattern)}")
        kinds = set(self.block_pattern)
        if kinds & {"attn", "local", "global", "moe", "dense", "mamba_sa"}:
            assert self.attention is not None, f"{self.name}: needs AttentionConfig"
        if "moe" in kinds:
            assert self.moe is not None, f"{self.name}: needs MoEConfig"
        if kinds & {"mamba", "mamba_sa"}:
            assert self.ssm is not None, f"{self.name}: needs SSMConfig"
        if "rwkv" in kinds:
            assert self.rwkv is not None, f"{self.name}: needs RWKVConfig"

    # -- derived ----------------------------------------------------------
    @property
    def head_dim(self) -> int:
        a = self.attention
        if a is None:
            return 0
        return a.head_dim if a.head_dim is not None else self.d_model // a.num_heads

    @property
    def num_super_blocks(self) -> int:
        return self.num_layers // len(self.block_pattern)

    @property
    def is_subquadratic(self) -> bool:
        """True if every block is O(seq) at decode with bounded state."""
        for kind in self.block_pattern:
            if kind in ("mamba", "rwkv", "mamba_sa"):
                continue  # mamba_sa shared-attn handled with bounded window at decode
            if kind == "local":
                continue
            if kind in ("attn",) and self.attention.window is not None:
                continue
            if kind == "global" and self.local_window is not None:
                # gemma2 global layers are capped to a window in long-context
                # serving mode (documented variant).
                return False
            return False
        return True

    @property
    def has_decode(self) -> bool:
        return not self.encoder_only

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One of the four assigned input shapes."""
    name: str
    seq_len: int
    global_batch: int
    mode: str                # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    microbatches: int = 1              # gradient accumulation
    remat: str = "none"                # none | block | full
    optimizer_state_dtype: str = "float32"   # "bfloat16" for the giant configs
    schedule: str = "cosine"
    seed: int = 0
    # -- fault tolerance (training/train_step.py skip-step guard) ----------
    # Loss scaling for bf16 stability: a float is a static scale (1.0 = off);
    # "dynamic" starts at 2^15, halves on every non-finite step, and doubles
    # after loss_scale_growth_interval consecutive finite steps (capped).
    loss_scale: object = 1.0           # float | "dynamic"
    loss_scale_growth_interval: int = 200
    # Non-finite steps are skipped (params/opt state untouched); the driver
    # fails fast once this many CONSECUTIVE steps have been skipped.
    max_skipped_steps: int = 25

    def __post_init__(self):
        if self.loss_scale != "dynamic":
            try:
                ok = float(self.loss_scale) > 0
            except (TypeError, ValueError):
                ok = False
            if not ok:
                raise ValueError(
                    f"TrainConfig.loss_scale must be a positive float or "
                    f"'dynamic', got {self.loss_scale!r}")
        if self.loss_scale_growth_interval < 1:
            raise ValueError(
                f"TrainConfig.loss_scale_growth_interval must be >= 1, got "
                f"{self.loss_scale_growth_interval}")
        if self.max_skipped_steps < 1:
            raise ValueError(
                f"TrainConfig.max_skipped_steps must be >= 1, got "
                f"{self.max_skipped_steps}")


@dataclass(frozen=True)
class MeshConfig:
    data: int = 16
    model: int = 16
    pods: int = 1

    @property
    def num_devices(self) -> int:
        return self.data * self.model * self.pods
