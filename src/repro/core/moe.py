"""The HetuMoE layer — paper Algorithm 1, expert-parallel over a mesh axis.

Per-device flow (inside ``shard_map``):

    1. gate            route(cfg, x·W)                     [core/gating]
    2. layout xform    plan + dispatch → (E·C, d)           [core/layout]
    3. AllToAll        flat | hierarchical over ``model``   [core/alltoall]
    4. experts         vmapped FFN over local experts
    5. AllToAll        return path (same mode)
    6. reverse xform   gather + weighted combine            [core/layout]

``cfg.dispatch == "grouped"`` replaces 2–6 with the dropless path:
expert-sorted (T·K, d) buffer + grouped/ragged expert matmuls, no
capacity padding.  Under expert parallelism the grouped AllToAll runs
instead of the capacity-padded one: per-expert counts cross the
``model`` axis first (a (M, E_local) int exchange), then each
destination rank's rows packed to a static segment bound B
(capacity.grouped_segment_bound; B = T·K by default → never drops);
the receive side rebuilds expert-major offsets from the counts and
feeds the same ragged matmuls, and the combine reverses the path.
Both a2a modes (flat / hierarchical) carry the token payload, so the
paper's two-stage win composes with dropless dispatch.  Expert-TP mode
(``expert_tp_axis``) composes too: the bounded expert-sorted chunks and
their counts are all-gathered over the TP axis into one expert-major
order every TP rank agrees on, each rank runs the grouped matmuls over
its f-slice of the expert weights, and a psum_scatter returns the
f-reduced token rows — see ``moe_block_local``.

Overlapped pipeline (``cfg.overlap_chunks = P > 1``, grouped dispatch
only): the bounded expert-sorted buffer is split into P static
``(·, B/P, d)`` microchunk windows (``layout.grouped_chunk_counts``
window-clips the count matrices; ``capacity.grouped_overlap_chunk_bound``
checks P divides the bound) and the per-chunk exchange → grouped-matmul
→ combine stages run as a statically-unrolled, double-buffered software
pipeline: window i+1's dispatch AllToAll is issued before window i's
matmuls consume the carried receive buffer, and each window's combine
AllToAll is consumed only at the drain — XLA's async collectives then
hide the steady-state exchange behind compute, leaving only the fill
(first dispatch) and drain (last combine) exposed (the α–β trade is
``alltoall.cost_pipelined``).  Composes with grouped-EP, expert-TP and
both a2a modes; the backward differentiates through the unrolled
pipeline into the same custom_vjp grouped kernels.  P = 1 is exactly
the serial path.

Tokens are sharded over EVERY mesh axis (the token axis is the product
batch·seq flattened): each of the D·M devices routes its own T/(D·M)
tokens.  Experts shard over ``model`` and replicate over ``data``/``pod``
(classic EP×DP); the AllToAll therefore runs inside each data-group's
row of model-ranks, and expert-weight gradients all-reduce over
``data``/``pod`` automatically through the ``shard_map`` transpose.

Token counts that don't divide the device count (decode batches) are
padded; padded tokens are routed to a virtual expert E (dropped by the
plan) so they consume no real capacity.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import alltoall, balance, capacity, gating, layout, tuning
from repro.core.compat import shard_map
from repro.core.config import MoEConfig


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------

def init_moe_params(rng: jax.Array, cfg: MoEConfig, d_model: int, d_ff: int,
                    num_experts: int, *, act: str = "swiglu",
                    dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    d_ff = cfg.d_ff_expert or d_ff
    k_gate, k_up, k_gt, k_out = jax.random.split(rng, 4)
    scale_in = d_model ** -0.5
    scale_out = d_ff ** -0.5
    p = {
        # router always in f32 — numerics matter more than bytes here
        "gate_w": (jax.random.normal(k_gate, (d_model, num_experts), jnp.float32)
                   * scale_in),
        # up / gate kept SEPARATE (not fused 2f) so the f dim shards
        # cleanly in expert-TP mode (§Perf, llama4 decode hillclimb)
        "w_up": (jax.random.normal(k_up, (num_experts, d_model, d_ff), jnp.float32)
                 * scale_in).astype(dtype),
        "w_out": (jax.random.normal(k_out, (num_experts, d_ff, d_model), jnp.float32)
                  * scale_out).astype(dtype),
    }
    if act in ("swiglu", "geglu"):
        p["w_gate"] = (jax.random.normal(
            k_gt, (num_experts, d_model, d_ff), jnp.float32)
            * scale_in).astype(dtype)
    return p


def expert_ffn(params: Dict[str, jax.Array], x: jax.Array,
               act: str) -> jax.Array:
    """(E_local, T, d) × expert weights → (E_local, T, d)."""
    h = jnp.einsum("etd,edf->etf", x, params["w_up"])
    if act in ("swiglu", "geglu"):
        g = jnp.einsum("etd,edf->etf", x, params["w_gate"])
        h = h * (jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g))
    elif act == "gelu":
        h = jax.nn.gelu(h)
    else:
        h = jax.nn.relu(h)
    return jnp.einsum("etf,efd->etd", h, params["w_out"])


# ---------------------------------------------------------------------------
# the per-device MoE block (runs inside shard_map)
# ---------------------------------------------------------------------------

def moe_block_local(cfg: MoEConfig, params: Dict[str, jax.Array], x: jax.Array,
                    *, num_experts: int, act: str,
                    model_axis: Optional[str] = None, model_size: int = 1,
                    pmean_axes: Tuple[str, ...] = (),
                    rng: Optional[jax.Array] = None,
                    token_ids: Optional[jax.Array] = None,
                    valid: Optional[jax.Array] = None,
                    expert_tp_axis: Optional[str] = None,
                    ) -> Tuple[jax.Array, jax.Array, Dict[str, jax.Array]]:
    """x: (T_local, d) → (y, aux_loss, metrics).  ``params`` hold LOCAL
    expert shards: w_up/w_gate/w_out have leading dim E_local (and, with
    ``expert_tp_axis`` set, a 1/R slice of the f dim, R the TP degree).

    Expert-TP ``dispatch="grouped"`` (the ragged-aware TP composition —
    no more silent rewrite to ``"sort"``): the per-rank bounded
    expert-sorted chunks and their count matrices are all-gathered over
    the TP axis, :func:`repro.core.layout.grouped_tp_gather_maps`
    rebuilds ONE expert-major row order every TP rank agrees on, each
    rank runs the grouped/ragged matmuls over its f-slice (swiglu/geglu
    are elementwise in f, so the up/gate slices compose locally), and a
    tiled ``psum_scatter`` over the token rows hands each rank back its
    own chunk with the f-contraction reduced.  Composes with grouped-EP:
    there the gathered chunks are the (M·B, d) exchange layouts, so the
    return AllToAll runs on the already-reduced rows unchanged."""
    T, d = x.shape
    E = num_experts
    E_local = E // model_size
    assert params["w_up"].shape[0] == E_local, (params["w_up"].shape, E_local)

    # -- 1. gate ----------------------------------------------------------
    logits = gating.router_logits(cfg, x, params["gate_w"])
    gate = gating.route(cfg, logits, rng=rng, token_ids=token_ids)
    if valid is not None:
        # padded tokens → virtual expert E: dropped by the plan, zero weight
        gate = gate._replace(
            expert_index=jnp.where(valid[:, None], gate.expert_index, E),
            combine_weights=jnp.where(valid[:, None], gate.combine_weights, 0.0))

    # -- 2. dispatch plan (ONE sort; aux metrics reuse its counts) ----------
    dispatch = cfg.dispatch
    tp = expert_tp_axis

    if dispatch == "grouped":
        # dropless: expert-sorted (T·K, d) buffer, no capacity padding;
        # the expert FFN runs as grouped/ragged matmuls over the segments.
        gplan = layout.plan_grouped(gate, E, drop_bucket=True)
        aux, metrics = balance.aux_losses(cfg, gate,
                                          expert_counts=gplan.counts,
                                          valid=valid, axes=pmean_axes)
        from repro.kernels import grouped_ffn as gffn
        from repro.kernels import ops as kops
        gather = kops.gather_rows if cfg.use_pallas_gate else layout.take_rows
        if model_size > 1:
            # grouped AllToAll (dropless EP): the expert-sorted buffer is
            # destination-rank-sorted too, so dispatch is one gather into
            # the static (M, B, d) exchange layout; counts cross first so
            # the receive side can rebuild its ragged offsets.
            B = capacity.grouped_segment_bound(cfg, T, model_size)
            eplan = layout.plan_grouped_ep(gplan, E, model_size, B)
            packed = gather(x, eplan.pack_map).reshape(model_size, B, d)
            send_counts = eplan.send_counts            # (M, E_local)
        else:
            B = capacity.grouped_tp_gather_bound(cfg, T)
            xs0 = (gather(x, gplan.token) if cfg.use_pallas_gate
                   else layout.dispatch_grouped(x, gplan))
            packed = xs0.reshape(1, B, d)              # the sorted buffer
            send_counts = gplan.counts[None]           # (1, E)
        n_src = packed.shape[0]
        # Wire dtype for the exchange payloads (MegaScale-MoE).  A no-op
        # without expert parallelism: the exchange is the identity, so
        # there is no wire to quantize — pure-TP meshes keep full
        # precision end to end.
        qdt = cfg.payload_dtype if model_size > 1 else None

        def exchange(chunk, counts):
            """Dispatch exchange of one bounded window (identity without
            expert parallelism).  With ``cfg.payload_dtype`` set the
            window crosses the mesh quantized (per-source-chunk amax
            scales riding the count matrix) and arrives dequantized back
            at the compute dtype — the downstream TP gather / row maps /
            grouped matmuls are unchanged."""
            if model_size > 1:
                if qdt is not None:
                    return alltoall.quantized_exchange(
                        chunk, counts, model_axis, mode=cfg.a2a,
                        inner=cfg.a2a_inner, payload_dtype=qdt)
                return alltoall.grouped_all_to_all(
                    chunk, counts, model_axis,
                    mode=cfg.a2a, inner=cfg.a2a_inner)
            return chunk, counts

        def compute(recv, counts, bc):
            """Grouped matmuls over one received window ``(n_src, bc, d)``
            + its count matrix, returning the FFN output in the SAME
            home/exchange layout (TP gathered & f-reduced, EP combine
            AllToAll'd back to the source ranks)."""
            if tp is not None:
                # ragged-aware expert TP: gather every TP rank's bounded
                # chunks + counts (the chunk layout is identical on all
                # ranks — the bound derives from static shapes only, see
                # capacity.grouped_tp_gather_bound), merge into one shared
                # expert-major order, and run this rank's f-slice.
                recv = lax.all_gather(recv, tp, axis=0, tiled=True)
                counts = lax.all_gather(counts, tp, axis=0, tiled=True)
            # the gathered chunk count is R·M by all_gather construction
            # (1 with neither TP nor EP) — the merged maps key off it
            n_chunks = recv.shape[0]
            if model_size > 1 or tp is not None:
                ffn_src, dst_map, group_sizes = layout.grouped_tp_gather_maps(
                    counts, bc)
                xs = gather(recv.reshape(n_chunks * bc, d), ffn_src)
            else:
                xs = recv.reshape(bc, d)
                group_sizes = counts[0]
            ys = gffn.grouped_ffn(params, xs.astype(params["w_up"].dtype),
                                  group_sizes, act,
                                  use_pallas=cfg.use_pallas_gate,
                                  interpret=kops.INTERPRET,
                                  block_m=(cfg.grouped_block_m
                                           or gffn.DEFAULT_BLOCK_M))
            if tp is not None:
                # back to chunk layout, then reduce the f-contraction
                # while scattering each TP rank its own rows (tiled:
                # chunk r of the summed (R·M·bc, d) array is rank r's
                # (M·bc, d) layout)
                h = gather(ys, dst_map)
                ys = lax.psum_scatter(h, tp, scatter_dimension=0,
                                      tiled=True)
            if model_size > 1:
                # expert-major FFN rows → exchange layout → AllToAll home
                h = (ys.reshape(model_size, bc, d) if tp is not None
                     else gather(ys, dst_map).reshape(model_size, bc, d))
                if qdt is not None:
                    # combine payload quantized like dispatch (the scales
                    # go over their own tiny flat exchange — no count
                    # matrix travels this direction) and dequantized to
                    # f32, so the weighted combine reduction below runs
                    # in f32 regardless of the compute dtype.
                    out, _ = alltoall.quantized_exchange(
                        h, None, model_axis, mode=cfg.a2a,
                        inner=cfg.a2a_inner, payload_dtype=qdt,
                        out_dtype=jnp.float32)
                    return out
                return alltoall.all_to_all(h, model_axis, mode=cfg.a2a,
                                           inner=cfg.a2a_inner)
            return ys.reshape(1, bc, d)

        n_overlap = cfg.overlap_chunks
        if n_overlap > 1:
            # overlapped pipeline: P static (n_src, Bc, d) windows of the
            # bounded buffer, software-pipelined with a double buffer —
            # window i+1's dispatch exchange is issued BEFORE window i's
            # grouped matmuls consume the carried receive buffer, and
            # each window's combine AllToAll is consumed only at the
            # drain, so XLA's async collectives overlap both directions
            # with compute.  Statically unrolled (P is a config int):
            # a fori_loop would fold the P exchanges into one loop-body
            # collective, hiding the pipeline from the scheduler (and
            # from the jaxpr witness tests).
            Bc = capacity.grouped_overlap_chunk_bound(cfg, B)
            chunk_counts = layout.grouped_chunk_counts(
                send_counts, B, n_overlap)             # (P, n_src, E_seg)
            windows = packed.reshape(n_src, n_overlap, Bc, d)
            recv, rcounts = exchange(windows[:, 0], chunk_counts[0])
            outs = []
            for i in range(n_overlap):
                if i + 1 < n_overlap:   # prefetch the next window's a2a
                    recv_nxt, rcounts_nxt = exchange(windows[:, i + 1],
                                                     chunk_counts[i + 1])
                outs.append(compute(recv, rcounts, Bc))
                if i + 1 < n_overlap:
                    recv, rcounts = recv_nxt, rcounts_nxt
            out = jnp.stack(outs, axis=1).reshape(n_src, B, d)
        else:
            out = compute(*exchange(packed, send_counts), B)

        if model_size > 1:
            # reverse path: combined exchange layout → this rank's
            # sorted rows → weighted combine
            ys = gather(out.reshape(model_size * B, d), eplan.back_map)
        else:
            ys = out.reshape(B, d)
        y = layout.combine_grouped(ys, gplan, T)
        if pmean_axes:
            aux = lax.pmean(aux, pmean_axes)
            metrics = {k: lax.pmean(v, pmean_axes) for k, v in metrics.items()}
        return y.astype(x.dtype), aux, metrics

    C = capacity.expert_capacity(cfg, T, E)
    if dispatch == "sort":
        plan = layout.plan_sort(gate, E, C, drop_bucket=True)
        if cfg.use_pallas_gate:
            # the blocked Pallas layout kernel replaces the jnp gather on
            # TPU, driven by the plan's sort-derived inverse row map;
            # interpret-mode equivalence is asserted in tests
            from repro.kernels import ops as kops
            buf = kops.layout_dispatch(x, plan.slot, E, C, inv=plan.inv)
        else:
            buf = layout.dispatch_scatter(x, plan, E, C)
    else:
        plan = layout.plan_cumsum(gate, E, C, drop_bucket=True)
        buf = layout.dispatch_dense(x, plan, E, C)
    aux, metrics = balance.aux_losses(cfg, gate, expert_counts=plan.counts,
                                      valid=valid, axes=pmean_axes)

    # -- 3. AllToAll (dispatch) ---------------------------------------------
    if model_size > 1:
        buf = buf.reshape(model_size, E_local * C, d)
        buf = alltoall.all_to_all(buf, model_axis, mode=cfg.a2a,
                                  inner=cfg.a2a_inner)
        # (M, E_local·C, d) source-major → (E_local, M·C, d)
        buf = (buf.reshape(model_size, E_local, C, d)
               .transpose(1, 0, 2, 3).reshape(E_local, model_size * C, d))
    else:
        buf = buf.reshape(E_local, C, d)

    # -- 4. experts -----------------------------------------------------------
    if expert_tp_axis is not None:
        # §Perf (llama4/dbrx decode hillclimb): expert TENSOR parallelism
        # over the data axis — weights stay sharded on their f dim; the
        # (tiny, decode-sized) token buffers are gathered across data,
        # every data-rank computes its f-slice of every local expert, and
        # a reduce-scatter returns each rank's own tokens.  Replaces the
        # per-layer multi-GB ZeRO-3 weight gather with MB-scale token
        # collectives.
        buf = lax.all_gather(buf, expert_tp_axis, axis=1, tiled=True)
        h = expert_ffn(params, buf.astype(params["w_up"].dtype), act)
        h = lax.psum_scatter(h, expert_tp_axis, scatter_dimension=1,
                             tiled=True)
    else:
        h = expert_ffn(params, buf.astype(params["w_up"].dtype), act)

    # -- 5. AllToAll (return) -------------------------------------------------
    if model_size > 1:
        h = (h.reshape(E_local, model_size, C, d)
             .transpose(1, 0, 2, 3).reshape(model_size, E_local * C, d))
        h = alltoall.all_to_all(h, model_axis, mode=cfg.a2a, inner=cfg.a2a_inner)
        h = h.reshape(E * C, d)
    else:
        h = h.reshape(E * C, d)

    # -- 6. reverse layout transform + combine --------------------------------
    if dispatch == "sort":
        if cfg.use_pallas_gate:
            from repro.kernels import ops as kops
            y = kops.layout_combine(h, plan.slot, plan.weight)
        else:
            y = layout.combine_gather(h, plan)
    else:
        y = layout.combine_dense(h, plan, E, C)

    if pmean_axes:
        aux = lax.pmean(aux, pmean_axes)
        metrics = {k: lax.pmean(v, pmean_axes) for k, v in metrics.items()}
    return y.astype(x.dtype), aux, metrics


# ---------------------------------------------------------------------------
# shard_map wrapper — the public MoE layer
# ---------------------------------------------------------------------------

def _pad_to(x: jax.Array, mult: int, axis: int = 0):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


def grouped_a2a_stages(cfg: MoEConfig, model_size: int) -> int:
    """Equations one payload exchange emits: 1 for flat, 2 for an
    EFFECTIVE hierarchical a2a (two-stage only when
    ``1 < a2a_inner < model_size`` divides evenly; otherwise
    ``core.alltoall`` runs flat).  The lint rules derive their
    payload-site expectations from this instead of back-solving the
    total equation count — the quantized path's extra scales exchange
    made that inversion ambiguous."""
    if (cfg.a2a == "hierarchical" and 1 < cfg.a2a_inner
            and model_size % cfg.a2a_inner == 0
            and model_size // cfg.a2a_inner > 1):
        return 2
    return 1


def expected_grouped_a2a_eqns(cfg: MoEConfig, model_size: int) -> int:
    """How many ``all_to_all`` equations the grouped dispatch path emits
    per layer application — the single source of truth for the
    ``overlap-chunk-count`` lint rule (``repro.analysis``) and the jaxpr
    witness tests, kept next to the pipeline that emits them.

    Per overlap window: one (flat) counts exchange, plus a dispatch and
    a combine payload exchange of :func:`grouped_a2a_stages` equations
    each.  With ``payload_dtype`` set, the combine direction adds one
    tiny flat scales exchange per window (the dispatch direction's
    scales ride the counts exchange as a bitcast column — zero extra
    equations; see ``alltoall.quantized_grouped_all_to_all``).
    ``overlap_chunks = P`` multiplies everything: the statically
    unrolled pipeline must emit P separate window exchanges — a ``fori_loop``
    would fold them into ONE loop-body equation (the PR 5 scheduler-
    hiding hazard the lint rule exists to catch).
    """
    if tuning.has_auto_knobs(cfg):
        # a sentinel here would be silently counted as flat/P="auto" —
        # the caller must hand over the concrete cell it actually traced
        raise ValueError(
            "expected_grouped_a2a_eqns needs a concrete config — resolve "
            "'auto' knobs first (core/tuning.resolve_moe_config)")
    if cfg.dispatch != "grouped" or model_size <= 1:
        return 0
    stages = grouped_a2a_stages(cfg, model_size)
    per_window = 1 + 2 * stages
    if cfg.payload_dtype is not None:
        per_window += 1                     # the combine scales exchange
    return cfg.overlap_chunks * per_window


def validate_dispatch_config(cfg: MoEConfig, *, model_size: int,
                             model_axis: str = "model",
                             tokens_per_shard: Optional[int] = None,
                             d_model: Optional[int] = None,
                             dtype=None) -> None:
    """Raise ``ValueError`` for cfg × mesh combinations that would
    otherwise only surface at trace time, deep inside ``shard_map``.

    Called by :func:`sharded_moe_apply` on every trace, and by the
    serving step-builder (``serving/engine.py``) at STEP-BUILD time so a
    bad serving configuration fails when the step is constructed — with
    the config fields named — instead of minutes later inside a decode
    trace.  With ``tokens_per_shard`` given (the static per-shard token
    count is known to the caller, e.g. the decode batch), the grouped
    overlap-pipeline bound divisibility is checked too
    (:func:`capacity.grouped_overlap_chunk_bound`).

    ``"auto"`` knobs (core/tuning.py) are resolved first when
    ``tokens_per_shard`` is known — the checks then run against, and any
    error message names, the RESOLVED values.  Without a token count
    there is nothing concrete to check yet: every sentinel resolves at a
    choke point where the count is static, and the resolver only emits
    combinations these checks accept.
    """
    auto_cfg = None
    if tuning.has_auto_knobs(cfg):
        if tokens_per_shard is None:
            return
        auto_cfg = cfg
        cfg = tuning.resolve_moe_config(
            cfg, model_size=model_size, tokens_per_shard=tokens_per_shard,
            d_model=d_model if d_model is not None else 1024, dtype=dtype)
    try:
        _validate_concrete(cfg, model_size=model_size, model_axis=model_axis,
                           tokens_per_shard=tokens_per_shard)
    except ValueError as e:
        if auto_cfg is not None:
            raise ValueError(
                f"{e} [{tuning.describe_resolution(auto_cfg, cfg)}]"
            ) from None
        raise


def _validate_concrete(cfg: MoEConfig, *, model_size: int,
                       model_axis: str,
                       tokens_per_shard: Optional[int]) -> None:
    if cfg.overlap_chunks > 1 and cfg.dispatch != "grouped":
        # the pipeline chunks the bounded expert-sorted buffer, which
        # only the grouped path builds — silently ignoring the setting
        # would fake an overlap win on the capacity-padded paths
        raise ValueError(
            f"MoEConfig.overlap_chunks={cfg.overlap_chunks} requires "
            f"dispatch='grouped' (the overlapped pipeline chunks the "
            f"grouped dispatch buffer), got dispatch="
            f"{cfg.dispatch!r}")
    if (cfg.a2a == "hierarchical" and cfg.a2a_inner > 1
            and model_size > 1 and model_size % cfg.a2a_inner != 0):
        raise ValueError(
            f"MoEConfig.a2a='hierarchical' with a2a_inner={cfg.a2a_inner} "
            f"does not divide the mesh {model_axis!r} axis size "
            f"{model_size} — pick a2a_inner from its divisors or use "
            f"a2a='flat'")
    if (tokens_per_shard is not None and cfg.dispatch == "grouped"
            and cfg.overlap_chunks > 1):
        B = (capacity.grouped_segment_bound(cfg, tokens_per_shard, model_size)
             if model_size > 1
             else capacity.grouped_tp_gather_bound(cfg, tokens_per_shard))
        capacity.grouped_overlap_chunk_bound(cfg, B)   # raises when P ∤ B


def sharded_moe_apply(mesh: jax.sharding.Mesh, cfg: MoEConfig,
                      params: Dict[str, jax.Array], x: jax.Array, *,
                      num_experts: int, act: str = "swiglu",
                      model_axis: str = "model",
                      rng: Optional[jax.Array] = None,
                      token_ids: Optional[jax.Array] = None,
                      expert_tp_axis: Optional[str] = None,
                      ) -> Tuple[jax.Array, jax.Array, Dict[str, jax.Array]]:
    """Apply the MoE layer to ``x: (..., d)`` under ``mesh``.

    Leading dims are flattened into one token axis, sharded over EVERY
    mesh axis; expert weights shard over ``model_axis``.
    """
    lead = x.shape[:-1]
    d = x.shape[-1]
    toks = x.reshape(-1, d)
    axis_names = tuple(mesh.axis_names)
    n_dev = mesh.devices.size
    model_size = mesh.shape[model_axis]

    toks, n_real = _pad_to(toks, n_dev)
    valid = (jnp.arange(toks.shape[0]) < n_real)
    if token_ids is not None:
        tid, _ = _pad_to(token_ids.reshape(-1), n_dev)
    elif cfg.gate == "hash":
        # the zeros placeholder below would hash EVERY token to the same
        # bucket — one expert takes all load and _gate_hash never notices
        raise ValueError(
            "cfg.gate='hash' routes by token id: pass token_ids to "
            "sharded_moe_apply (the zeros fallback would silently send "
            "every token to one expert)")
    else:
        tid = jnp.zeros((toks.shape[0],), jnp.int32)

    if rng is None:
        rng = jax.random.PRNGKey(0)

    # §Perf H2 (dbrx train hillclimb): gather expert weights in the
    # COMPUTE dtype.  The cast is outside shard_map, so the ZeRO-3
    # all-gather XLA inserts at the shard_map boundary moves bf16, not
    # f32 — halving the largest FSDP collective and its HBM transient.
    params = {k: (v.astype(x.dtype) if k != "gate_w" else v)
              for k, v in params.items()}

    # trace-time "auto" resolution (core/tuning.py): the per-shard token
    # count, width and dtype are all static here, so the resolved cfg is
    # a pure function of the traced shapes — the same call shape always
    # resolves (and therefore traces) identically.
    cfg = tuning.resolve_moe_config(
        cfg, model_size=model_size, tokens_per_shard=toks.shape[0] // n_dev,
        d_model=d, dtype=x.dtype)
    validate_dispatch_config(cfg, model_size=model_size,
                             model_axis=model_axis)

    tok_spec = P(axis_names)
    tp = None
    if expert_tp_axis is not None:
        if expert_tp_axis not in axis_names:
            # a typo'd axis must not silently disable expert TP
            raise ValueError(
                f"expert_tp_axis={expert_tp_axis!r} is not an axis of the "
                f"mesh; valid axis names: {axis_names}")
        tp = expert_tp_axis
    param_specs = {"gate_w": P(None, None),
                   "w_up": P(model_axis, None, tp),
                   "w_out": P(model_axis, tp, None)}
    if "w_gate" in params:
        param_specs["w_gate"] = P(model_axis, None, tp)

    def local_fn(params, toks, valid, tid, rng):
        idx = lax.axis_index(axis_names)
        rng = jax.random.fold_in(rng, idx)
        return moe_block_local(
            cfg, params, toks, num_experts=num_experts, act=act,
            model_axis=model_axis, model_size=model_size,
            pmean_axes=axis_names, rng=rng,
            token_ids=tid, valid=valid, expert_tp_axis=tp)

    # metric out_specs come from balance's canonical key list — a metric
    # added there must not desync this spec tree (shard_map fails with an
    # opaque pytree-mismatch error when it does)
    y, aux, metrics = shard_map(
        local_fn, mesh=mesh,
        in_specs=(param_specs, tok_spec, tok_spec, tok_spec, P()),
        out_specs=(tok_spec, P(), {k: P() for k in balance.METRIC_KEYS}),
        check_vma=False,
    )(params, toks, valid, tid, rng)

    y = y[:n_real].reshape(*lead, d)
    return y, aux, metrics
