"""Gating strategies — the breadth axis of HetuMoE (paper Fig. 2).

Every strategy maps router logits ``(S, E)`` to a :class:`GateOutput`
with STATIC shapes ``(S, K)`` — a hard requirement on TPU/XLA.  The
strategies (paper §3.1):

=================  ==========================================================
``topk``           Shazeer et al. 2017 — ``g = softmax(TopK(x·W, K))``
``switch``         Fedus et al. 2021 — Top-1 of the full softmax
``gshard``         Lepikhin et al. 2020 — Top-2; 2nd expert stochastically
                   sampled ∝ prob (deterministic 2nd argmax if no rng)
``ktop1``          M6-T — experts split into ``num_prototypes`` prototypes,
                   Top-1 within each, outputs summed
``sam``            SAM — hierarchical: Switch router over ``num_groups``
                   device-groups, Mixture Top-k inside the chosen group
``base``           BASE layer — balanced linear assignment.  We solve the
                   relaxed assignment with Sinkhorn iterations (the
                   TPU-friendly formulation used by S-BASE; the exact
                   auction algorithm of the paper is host-sequential)
``hash``           Hash layer — token-id bucket hashing, parameter-free
``dense_to_sparse``Nie et al. 2021 — Gumbel-softmax routing annealed by a
                   temperature schedule from dense to sparse
=================  ==========================================================

The gate runs in ``router_dtype`` (default f32) regardless of the model
compute dtype — router numerics dominate MoE training stability.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.config import MoEConfig


class GateOutput(NamedTuple):
    """Routing decision for a group of S tokens (static shapes).

    ``expert_index``    (S, K) int32  — target expert per assignment slot
    ``combine_weights`` (S, K) f32    — weight used in the output combine
    ``router_probs``    (S, E) f32    — full distribution (aux losses)
    ``logits``          (S, E) f32    — raw router logits (z-loss)
    """
    expert_index: jax.Array
    combine_weights: jax.Array
    router_probs: jax.Array
    logits: jax.Array

    @property
    def k(self) -> int:
        return self.expert_index.shape[-1]


def _topk(logits: jax.Array, k: int):
    """Top-k values+indices.  For the k∈{1,2} fast path use iterative max
    (O(k·E), what the Pallas kernel implements) instead of XLA sort."""
    if k == 1:
        idx = jnp.argmax(logits, axis=-1, keepdims=True)
        val = jnp.take_along_axis(logits, idx, axis=-1)
        return val, idx.astype(jnp.int32)
    if k == 2:
        i1 = jnp.argmax(logits, axis=-1, keepdims=True)
        v1 = jnp.take_along_axis(logits, i1, axis=-1)
        masked = jnp.where(
            jax.nn.one_hot(i1[..., 0], logits.shape[-1], dtype=bool),
            -jnp.inf, logits)
        i2 = jnp.argmax(masked, axis=-1, keepdims=True)
        v2 = jnp.take_along_axis(masked, i2, axis=-1)
        return (jnp.concatenate([v1, v2], -1),
                jnp.concatenate([i1, i2], -1).astype(jnp.int32))
    val, idx = jax.lax.top_k(logits, k)
    return val, idx.astype(jnp.int32)


# ---------------------------------------------------------------------------
# individual strategies
# ---------------------------------------------------------------------------

def _gate_topk(cfg: MoEConfig, logits, rng, token_ids):
    """Paper Eq. 1: softmax over the K selected logits."""
    val, idx = _topk(logits, cfg.top_k)
    weights = jax.nn.softmax(val, axis=-1)
    probs = jax.nn.softmax(logits, axis=-1)
    return GateOutput(idx, weights, probs, logits)


def _gate_switch(cfg: MoEConfig, logits, rng, token_ids):
    probs = jax.nn.softmax(logits, axis=-1)
    idx = jnp.argmax(probs, axis=-1, keepdims=True).astype(jnp.int32)
    weights = jnp.take_along_axis(probs, idx, axis=-1)
    return GateOutput(idx, weights, probs, logits)


def _gate_gshard(cfg: MoEConfig, logits, rng, token_ids):
    probs = jax.nn.softmax(logits, axis=-1)
    E = logits.shape[-1]
    i1 = jnp.argmax(probs, axis=-1)
    g1 = jnp.take_along_axis(probs, i1[:, None], axis=-1)[:, 0]
    first = jax.nn.one_hot(i1, E, dtype=bool)
    masked = jnp.where(first, 0.0, probs)
    if rng is not None:
        # GShard samples the 2nd expert proportionally to its prob.  The
        # 1st expert's slot must be -inf in log space: an additive floor
        # (log(masked + eps)) leaves it samplable whenever the other
        # probs are below eps — re-picking i1 with weight 0 in the
        # denominator skew.
        i2 = jax.random.categorical(
            rng, jnp.where(first, -jnp.inf, jnp.log(probs + 1e-9)), axis=-1)
    else:
        i2 = jnp.argmax(masked, axis=-1)
    g2 = jnp.take_along_axis(masked, i2[:, None], axis=-1)[:, 0]
    denom = g1 + g2 + 1e-9
    idx = jnp.stack([i1, i2], axis=-1).astype(jnp.int32)
    weights = jnp.stack([g1 / denom, g2 / denom], axis=-1)
    return GateOutput(idx, weights, probs, logits)


def _gate_ktop1(cfg: MoEConfig, logits, rng, token_ids):
    """M6-T: E = P·(E/P) prototypes; Top-1 inside each prototype, summed."""
    S, E = logits.shape
    P = cfg.num_prototypes
    assert E % P == 0, f"ktop1: {E} experts not divisible by {P} prototypes"
    per = E // P
    lp = logits.reshape(S, P, per)
    probs_p = jax.nn.softmax(lp, axis=-1)            # softmax inside prototype
    local = jnp.argmax(lp, axis=-1)                  # (S, P)
    w = jnp.take_along_axis(probs_p, local[..., None], axis=-1)[..., 0]
    idx = (local + jnp.arange(P, dtype=local.dtype)[None, :] * per)
    probs = probs_p.reshape(S, E) / P                # proper distribution
    return GateOutput(idx.astype(jnp.int32), w, probs, logits)


def _gate_sam(cfg: MoEConfig, logits, rng, token_ids):
    """SAM (H Top-k): Switch router picks ONE group (= one device's experts),
    Mixture router picks Top-k inside it — remote activations avoided."""
    S, E = logits.shape
    G = cfg.num_groups
    assert E % G == 0, f"sam: {E} experts not divisible by {G} groups"
    per = E // G
    k = min(cfg.top_k, per)
    lg = logits.reshape(S, G, per)
    group_score = jax.nn.logsumexp(lg, axis=-1)          # (S, G) switch router
    gsel = jnp.argmax(group_score, axis=-1)              # (S,)
    chosen = jnp.take_along_axis(lg, gsel[:, None, None], axis=1)[:, 0]  # (S, per)
    val, local = _topk(chosen, k)
    weights = jax.nn.softmax(val, axis=-1)
    idx = (local + (gsel[:, None] * per).astype(jnp.int32))
    group_probs = jax.nn.softmax(group_score, axis=-1)
    probs = (jax.nn.softmax(lg, axis=-1) * group_probs[..., None]).reshape(S, E)
    return GateOutput(idx.astype(jnp.int32), weights, probs, logits)


def _gate_base(cfg: MoEConfig, logits, rng, token_ids,
               n_iters: int = 8, eps: float = 1.0):
    """BASE layer via Sinkhorn: maximize Σ x_i·w_{a_i} s.t. balanced loads
    (paper Eq. 2).  Entropic relaxation, ``n_iters`` normalization sweeps in
    log space, then per-token argmax of the transport plan."""
    S, E = logits.shape
    log_pi = logits / eps
    for _ in range(n_iters):
        log_pi = log_pi - jax.nn.logsumexp(log_pi, axis=1, keepdims=True)
        log_pi = log_pi - jax.nn.logsumexp(log_pi, axis=0, keepdims=True) \
            + jnp.log(jnp.asarray(S / E, log_pi.dtype))
    idx = jnp.argmax(log_pi, axis=-1, keepdims=True).astype(jnp.int32)
    # BASE combines with σ(score) of the assigned expert (no softmax,
    # no auxiliary loss — balance is structural).
    score = jnp.take_along_axis(logits, idx, axis=-1)
    weights = jax.nn.sigmoid(score)
    probs = jax.nn.softmax(logits, axis=-1)
    return GateOutput(idx, weights, probs, logits)


def _gate_hash(cfg: MoEConfig, logits, rng, token_ids):
    """Hash layer: parameter-free token-id bucketing (Roller et al.)."""
    S, E = logits.shape
    if token_ids is None:
        raise ValueError("hash gate requires token_ids")
    h = token_ids.astype(jnp.uint32)
    # Knuth multiplicative hash — a fixed 'random hash' of the vocabulary.
    h = (h * jnp.uint32(2654435761)) ^ (h >> 16)
    idx = (h % jnp.uint32(E)).astype(jnp.int32)[:, None]
    weights = jnp.ones((S, 1), dtype=logits.dtype)
    probs = jax.nn.one_hot(idx[:, 0], E, dtype=logits.dtype)
    return GateOutput(idx, weights, probs, logits)


def _gate_dense_to_sparse(cfg: MoEConfig, logits, rng, token_ids):
    """Dense-to-Sparse: Gumbel-softmax with annealed temperature.  At high T
    the distribution is near-uniform (dense routing across the K slots); as
    T → 0 it collapses onto the argmax (sparse).  K = cfg.top_k slots."""
    T = jnp.asarray(cfg.gumbel_temperature, logits.dtype)
    if rng is not None:
        g = -jnp.log(-jnp.log(
            jax.random.uniform(rng, logits.shape, logits.dtype, 1e-6, 1.0)))
        noisy = (logits + g) / T
    else:
        noisy = logits / T
    y = jax.nn.softmax(noisy, axis=-1)
    val, idx = _topk(y, cfg.top_k)
    # weights are the (unrenormalized) gumbel-softmax probabilities: the
    # annealing shifts mass onto slot 0 as T decreases.
    return GateOutput(idx, val, y, logits)


_GATES = {
    "topk": _gate_topk,
    "switch": _gate_switch,
    "gshard": _gate_gshard,
    "ktop1": _gate_ktop1,
    "sam": _gate_sam,
    "base": _gate_base,
    "hash": _gate_hash,
    "dense_to_sparse": _gate_dense_to_sparse,
}


def gate_k(cfg: MoEConfig) -> int:
    """Static number of assignment slots per token for a strategy.

    This is THE contract the capacity/bound sizing and the dispatch
    plans build on: it must equal the K that ``route()`` actually
    emits.  For ``sam`` that means the same clamp ``_gate_sam`` applies
    — top-k runs INSIDE the chosen group, so a ``top_k`` above the
    group width E/G yields E/G slots, not ``top_k`` (returning the raw
    ``top_k`` tripped ``route()``'s shape assert and over-sized
    ``expert_capacity``/``grouped_segment_bound``)."""
    if cfg.gate in ("switch", "base", "hash"):
        return 1
    if cfg.gate == "gshard":
        return 2
    if cfg.gate == "ktop1":
        return cfg.num_prototypes
    if cfg.gate == "sam":
        return min(cfg.top_k, cfg.num_experts // cfg.num_groups)
    return cfg.top_k


def _route_pallas(cfg: MoEConfig, logits: jax.Array) -> GateOutput:
    """Fast path for topk/switch: the fused Pallas kernel does the top-k
    SELECTION (integer indices — inherently non-differentiable) and hands
    back its single-pass softmax stats; the probabilities and combine
    weights are derived from those stats (no second full softmax pass)
    in a way that stays exactly differentiable in the logits, so the
    router still trains — see ``ops.topk_softmax_weights``."""
    from repro.kernels import ops as kops  # lazy: kernels are optional
    k = gate_k(cfg)
    idx, sel_probs, probs = kops.topk_softmax_weights(logits, k)
    if cfg.gate == "topk":
        vals = jnp.take_along_axis(logits, idx, axis=-1)
        weights = jax.nn.softmax(vals, axis=-1)
    else:  # switch
        weights = sel_probs
    return GateOutput(idx, weights, probs, logits)


def route(cfg: MoEConfig, logits: jax.Array, *,
          rng: Optional[jax.Array] = None,
          token_ids: Optional[jax.Array] = None) -> GateOutput:
    """Dispatch a (S, E) logits tensor through the configured strategy."""
    logits = logits.astype(jnp.float32)
    if cfg.use_pallas_gate and cfg.gate in ("topk", "switch"):
        return _route_pallas(cfg, logits)
    out = _GATES[cfg.gate](cfg, logits, rng, token_ids)
    assert out.expert_index.shape[-1] == gate_k(cfg), (
        cfg.gate, out.expert_index.shape, gate_k(cfg))
    return out


def router_logits(cfg: MoEConfig, x: jax.Array, gate_w: jax.Array) -> jax.Array:
    """x·W in router_dtype (paper computes the gate in f32)."""
    dt = jnp.dtype(cfg.router_dtype)
    return x.astype(dt) @ gate_w.astype(dt)
