"""Version compatibility shims.

``shard_map`` moved from ``jax.experimental.shard_map`` to ``jax.shard_map``
(and renamed ``check_rep`` → ``check_vma``) across jax releases; the pinned
jax 0.4.37 only has the experimental spelling.  Callers import it from here
and always use the new-style ``check_vma`` keyword.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):          # jax ≥ 0.6 public API
    shard_map = jax.shard_map
else:                                  # jax 0.4.x experimental API
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma)
