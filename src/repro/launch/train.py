"""End-to-end training driver.

CPU-scale usage (the examples use this):
  PYTHONPATH=src python -m repro.launch.train --arch hetumoe-paper-16e \\
      --steps 200 --batch 8 --seq 128 --smoke

On a real pod the same driver runs with ``--mesh 16x16`` under the
production mesh; data parallel input feeding is per-host via the
deterministic synthetic pipeline (every host generates its shard).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro import configs
from repro.core.config import TrainConfig
from repro.data import SyntheticLM
from repro.launch import mesh as mesh_lib
from repro.training import make_train_step
from repro.training.train_step import init_train_state
from repro.checkpoint import save_checkpoint


def run(arch: str, *, steps: int, batch: int, seq: int, smoke: bool,
        lr: float = 3e-3, microbatches: int = 1, remat: str = "none",
        mesh_shape=(1, 1), log_every: int = 10, ckpt_dir: str = None,
        seed: int = 0):
    cfg = configs.smoke_config(arch) if smoke else configs.get_config(arch)
    tcfg = TrainConfig(learning_rate=lr, warmup_steps=max(steps // 10, 1),
                       total_steps=steps, microbatches=microbatches,
                       remat=remat, seed=seed)
    mesh = mesh_lib.make_smoke_mesh(tuple(mesh_shape))
    rng = jax.random.PRNGKey(seed)
    state = init_train_state(rng, cfg, tcfg)
    n_params = sum(np.prod(p.shape) for p in jax.tree.leaves(state.params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M mesh={dict(mesh.shape)}")
    ds = SyntheticLM(cfg, batch=batch, seq_len=seq, seed=seed)
    step_fn = jax.jit(make_train_step(cfg, tcfg, mesh), donate_argnums=(0,))
    history = []
    t0 = time.time()
    for s in range(steps):
        bt = ds.next_batch(s)
        state, m = step_fn(state, bt, jax.random.fold_in(rng, s))
        if s % log_every == 0 or s == steps - 1:
            m = {k: float(v) for k, v in m.items()}
            dt = time.time() - t0
            tput = batch * seq * (s + 1) / max(dt, 1e-9)
            print(f"step {s:5d} loss {m['loss']:.4f} ce {m['ce']:.4f} "
                  f"aux {m['aux']:.4f} gnorm {m['grad_norm']:.2f} "
                  f"tok/s {tput:,.0f}")
            history.append({"step": s, **m})
    if ckpt_dir:
        save_checkpoint(ckpt_dir, state, steps)
        print("checkpoint saved to", ckpt_dir)
    return state, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU scale)")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none", choices=["none", "block", "full"])
    ap.add_argument("--mesh", default="1x1",
                    help="DxM data×model mesh, e.g. 1x1 (CPU) or 16x16")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    mesh_shape = tuple(int(x) for x in args.mesh.split("x"))
    run(args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
        smoke=args.smoke, lr=args.lr, microbatches=args.microbatches,
        remat=args.remat, mesh_shape=mesh_shape, ckpt_dir=args.ckpt_dir)


if __name__ == "__main__":
    main()
