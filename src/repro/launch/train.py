"""End-to-end training driver with crash-safe resume.

CPU-scale usage (the examples use this):
  PYTHONPATH=src python -m repro.launch.train --arch hetumoe-paper-16e \\
      --steps 200 --batch 8 --seq 128 --smoke

On a real pod the same driver runs with ``--mesh 16x16`` under the
production mesh; data parallel input feeding is per-host via the
deterministic synthetic pipeline (every host generates its shard).

Fault tolerance: ``--ckpt-every N`` saves atomically every N steps
(keep-last ``--ckpt-keep``); ``--resume`` restores the newest *intact*
checkpoint and continues — because the synthetic pipeline and rng are
keyed by the global step, a killed-and-resumed run reproduces the
uninterrupted loss trajectory bitwise.  Non-finite steps are skipped by
the train step (see ``training/train_step.py``); the driver fails fast
once ``TrainConfig.max_skipped_steps`` CONSECUTIVE steps were skipped.
``--history-out`` dumps the per-step metric history as JSON so resume
tests and bench tooling diff trajectories without parsing stdout, and
``--inject site:mode@steps`` arms the deterministic fault harness
(``core/faults.py``) from the CLI.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

import jax

from repro import configs
from repro.core import faults as faults_mod
from repro.core import tuning
from repro.core.config import TrainConfig
from repro.data import SyntheticLM
from repro.launch import mesh as mesh_lib
from repro.training import make_train_step
from repro.training.train_step import init_train_state
from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint


def run(arch: str, *, steps: int, batch: int, seq: int, smoke: bool,
        lr: float = 3e-3, microbatches: int = 1, remat: str = "none",
        mesh_shape=(1, 1), log_every: int = 10, ckpt_dir: str = None,
        ckpt_every: int = None, ckpt_keep: int = 3, resume: bool = False,
        seed: int = 0, loss_scale="none", history_out: str = None,
        faults: faults_mod.FaultPlan = None, tune: str = "auto",
        fabric=None):
    if (ckpt_every or resume) and not ckpt_dir:
        raise ValueError("--ckpt-every/--resume require --ckpt-dir")
    cfg = configs.smoke_config(arch) if smoke else configs.get_config(arch)
    ls = 1.0 if loss_scale in (None, "none") else (
        "dynamic" if loss_scale == "dynamic" else float(loss_scale))
    tcfg = TrainConfig(learning_rate=lr, warmup_steps=max(steps // 10, 1),
                       total_steps=steps, microbatches=microbatches,
                       remat=remat, seed=seed, loss_scale=ls)
    mesh = mesh_lib.make_smoke_mesh(tuple(mesh_shape))
    tmode, tfab = tuning.configure(tune, fabric, mesh=mesh)
    if cfg.moe is not None:
        print(f"tune={tmode} fabric={tfab}")
    rng = jax.random.PRNGKey(seed)
    state = init_train_state(rng, cfg, tcfg)
    start = 0
    if resume:
        if latest_step(ckpt_dir) is not None:
            state, start = restore_checkpoint(ckpt_dir, state)
            print(f"resumed from step {start} ({ckpt_dir})")
        else:
            print(f"--resume: no checkpoint under {ckpt_dir}, starting fresh")
    n_params = sum(np.prod(p.shape) for p in jax.tree.leaves(state.params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M mesh={dict(mesh.shape)}")
    ds = SyntheticLM(cfg, batch=batch, seq_len=seq, seed=seed)
    step_fn = jax.jit(make_train_step(cfg, tcfg, mesh, faults=faults),
                      donate_argnums=(0,))
    history = []
    t0 = time.time()
    with faults_mod.active(faults):
        for s in range(start, steps):
            faults_mod.crash_point("train.loop", index=s)
            bt = ds.next_batch(s)
            state, m = step_fn(state, bt, jax.random.fold_in(rng, s))
            m = {k: float(v) for k, v in m.items()}
            history.append({"step": s, **m})
            if s % log_every == 0 or s == steps - 1:
                dt = time.time() - t0
                tput = batch * seq * (s + 1 - start) / max(dt, 1e-9)
                print(f"step {s:5d} loss {m['loss']:.4f} ce {m['ce']:.4f} "
                      f"aux {m['aux']:.4f} gnorm {m['grad_norm']:.2f} "
                      f"skip {m['skipped']:.0f} streak "
                      f"{m['nonfinite_streak']:.0f} tok/s {tput:,.0f}")
            if m["nonfinite_streak"] >= tcfg.max_skipped_steps:
                raise RuntimeError(
                    f"aborting at step {s}: {int(m['nonfinite_streak'])} "
                    f"consecutive non-finite steps were skipped (>= "
                    f"max_skipped_steps={tcfg.max_skipped_steps}) — the run "
                    f"is diverging; restore an earlier checkpoint, lower the "
                    f"lr, or enable loss_scale='dynamic'")
            if ckpt_every and (s + 1) % ckpt_every == 0 and s + 1 < steps:
                save_checkpoint(ckpt_dir, state, s + 1, keep=ckpt_keep)
    if ckpt_dir:
        save_checkpoint(ckpt_dir, state, steps, keep=ckpt_keep)
        print("checkpoint saved to", ckpt_dir)
    if history_out:
        with open(history_out, "w") as f:
            json.dump({"arch": cfg.name, "steps": steps, "start": start,
                       "resumed": bool(resume and start), "seed": seed,
                       "history": history}, f, indent=1)
        print("history written to", history_out)
    return state, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU scale)")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none", choices=["none", "block", "full"])
    ap.add_argument("--mesh", default="1x1", type=mesh_lib.mesh_cli_arg,
                    help="DxM data×model mesh, e.g. 1x1 (CPU) or 16x16")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=None,
                    help="save an atomic checkpoint every N steps")
    ap.add_argument("--ckpt-keep", type=int, default=3,
                    help="retain only the newest K checkpoints")
    ap.add_argument("--resume", action="store_true",
                    help="restore the newest intact checkpoint in --ckpt-dir")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--loss-scale", default="none",
                    help="'none', 'dynamic', or a static float (bf16 stability)")
    ap.add_argument("--history-out", default=None,
                    help="dump the per-step metric history as JSON")
    ap.add_argument("--inject", action="append", default=[],
                    help="fault spec 'site:mode@steps' (repeatable), e.g. "
                         "'train.grads:nan@3' or 'ckpt.data_tmp_written:kill@20'")
    ap.add_argument("--tune", default="auto",
                    choices=list(tuning.TUNE_MODES),
                    help="'auto' resolves MoEConfig 'auto' knobs from the "
                         "α–β cost model, 'off' pins them to the static "
                         "defaults, 'calibrate' measures a few AllToAll "
                         "shapes once and fits α–β (persisted to "
                         "TUNE_moe.json)")
    ap.add_argument("--fabric", default="ici_dcn",
                    type=mesh_lib.fabric_cli_arg,
                    help="named fast/slow LinkSpec pair the tuner scores "
                         "against (ici_dcn | pcie_eth100)")
    args = ap.parse_args()
    faults = faults_mod.plan_from_specs(args.inject) if args.inject else None
    run(args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
        smoke=args.smoke, lr=args.lr, microbatches=args.microbatches,
        remat=args.remat, mesh_shape=args.mesh, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, ckpt_keep=args.ckpt_keep,
        resume=args.resume, log_every=args.log_every, seed=args.seed,
        loss_scale=args.loss_scale, history_out=args.history_out,
        faults=faults, tune=args.tune, fabric=args.fabric)


if __name__ == "__main__":
    main()
