"""Static roofline analysis of compiled HLO — correcting XLA's
cost_analysis, which counts while-loop bodies ONCE (a scan over 30
super-blocks reports 1/30th of the real FLOPs).

The analyzer parses the compiled module text into computations, walks the
call graph propagating loop-trip multipliers, and derives:

  flops        2·M·N·K summed over every `dot` (and conv), ×multiplier
  hbm_bytes    per top-level op: Σ operand sizes + result size — the
               fusion boundary IS the HBM traffic unit in XLA, so this is
               a principled traffic model (ops inside fused computations
               are register/VMEM-internal and excluded)
  collectives  wire bytes per op (ring-algorithm cost by kind), ×multiplier,
               with the replica-group size and pod-crossing flag

Loop trip counts come from the integer constant in each while's condition
computation (scan lowers to `compare(iter, constant(N))`).
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16,
                # fp8 families (ROADMAP low-precision AllToAll payloads):
                # without these a quantized exchange buffer silently drops
                # out of the HBM/collective byte counts
                "f8e4m3fn": 1, "f8e4m3fnuz": 1, "f8e4m3b11fnuz": 1,
                "f8e5m2": 1, "f8e5m2fnuz": 1, "f8e4m3": 1, "f8e3m4": 1}

# longest-first so the regex alternation cannot stop at a prefix
# (``f8e4m3fn`` is a prefix of ``f8e4m3fnuz``)
_DTYPE_ALT = "|".join(sorted(_DTYPE_BYTES, key=len, reverse=True))

_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")
_KIND_RE = re.compile(r"\s*([\w\-]+)\(")


def _parse_def(line: str):
    """'%x = TYPE op(...)' → (name, type_text, kind) or None.  Handles
    tuple types with nested parens and /*index=N*/ comments."""
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    if rest.startswith("("):
        depth = 0
        i = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        type_text, tail = rest[:i + 1], rest[i + 1:]
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_text, tail = rest[:sp], rest[sp:]
    km = _KIND_RE.match(tail)
    if not km:
        return None
    return name, type_text, km.group(1)
_SHAPE_RE = re.compile(r"(" + _DTYPE_ALT + r")\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(")
_CALL_RE = re.compile(r"(?:calls=|to_apply=|condition=|body=)%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_IOTA_RE = re.compile(r"<=\[([0-9,]+)\]")

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "all-to-all",
                    "reduce-scatter", "collective-permute")


def _shape_bytes_and_dims(type_text: str) -> Tuple[int, List[Tuple[str, List[int]]]]:
    total = 0
    shapes = []
    for m in _SHAPE_RE.finditer(type_text):
        dims = [int(d) for d in m.group(2).split(",") if d]
        n = int(np.prod(dims)) if dims else 1
        total += n * _DTYPE_BYTES[m.group(1)]
        shapes.append((m.group(1), dims))
    return total, shapes


class Op:
    __slots__ = ("name", "kind", "result_bytes", "result_dims", "line")

    def __init__(self, name, kind, result_bytes, result_dims, line):
        self.name, self.kind = name, kind
        self.result_bytes, self.result_dims = result_bytes, result_dims
        self.line = line


def parse_module(txt: str):
    """→ (computations: name → [Op], shapes: op name → (bytes, dims))."""
    comps: Dict[str, List[Op]] = {}
    shapes: Dict[str, Tuple[int, List]] = {}
    cur: Optional[str] = None
    for line in txt.splitlines():
        stripped = line.strip()
        hdr = _COMP_HDR.match(stripped) \
            if stripped.endswith("{") and "->" in line else None
        if hdr:
            cur = hdr.group(1)
            comps[cur] = []
            continue
        if line.startswith("}"):
            cur = None
            continue
        d = _parse_def(line)
        if d is None or cur is None:
            continue
        name, type_text, kind = d
        rb, rd = _shape_bytes_and_dims(type_text)
        shapes[name] = (rb, rd)
        comps[cur].append(Op(name, kind, rb, rd, line))
    return comps, shapes


def _trip_count(cond_ops: List[Op]) -> int:
    """Largest integer constant in the condition computation."""
    best = 1
    for op in cond_ops:
        if op.kind == "constant":
            m = re.search(r"constant\((\d+)\)", op.line)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _dot_flops(op: Op, shapes) -> float:
    """2 · numel(result) · Π lhs contracting dims."""
    if op.kind not in ("dot", "convolution"):
        return 0.0
    if op.kind == "convolution":
        # rough: 2 · numel(result) · (kernel spatial · in_channels) — convs
        # only appear in the (tiny) mamba conv path here; treat via rhs
        m = _OPERAND_RE.findall(op.line.split("(", 1)[1])
        if len(m) >= 2 and m[1] in shapes:
            kb, kd = shapes[m[1]]
            numel_r = op.result_bytes and int(
                np.prod(op.result_dims[0][1])) if op.result_dims else 0
            k_numel = int(np.prod(kd[0][1])) if kd else 0
            out_ch = kd[0][1][-1] if kd and kd[0][1] else 1
            return 2.0 * numel_r * (k_numel / max(out_ch, 1))
        return 0.0
    mm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    if not mm:
        return 0.0
    cdims = [int(x) for x in mm.group(1).split(",") if x]
    args = _OPERAND_RE.findall(op.line.split("dot(", 1)[1])
    if not args or args[0] not in shapes:
        return 0.0
    _, lhs_shapes = shapes[args[0]]
    if not lhs_shapes:
        return 0.0
    lhs_dims = lhs_shapes[0][1]
    k = 1
    for c in cdims:
        if c < len(lhs_dims):
            k *= lhs_dims[c]
    numel_r = int(np.prod(op.result_dims[0][1])) if op.result_dims else 0
    return 2.0 * numel_r * k


def _collective(op: Op, pod_size: int) -> Optional[Dict[str, Any]]:
    kind = op.kind.replace("-start", "")
    if kind not in COLLECTIVE_KINDS:
        return None
    size = op.result_bytes
    gm = _GROUPS_RE.search(op.line)
    gsize = int(gm.group(2)) if gm else 1
    ngroups = int(gm.group(1)) if gm else 1
    crosses_pod = False
    im = _IOTA_RE.search(op.line)
    if im:
        iota = [int(x) for x in im.group(1).split(",")]
        total = int(np.prod(iota))
        if total > pod_size and gsize > 1:
            crosses_pod = ngroups * gsize > pod_size and \
                total // iota[0] < gsize * ngroups
    if kind == "all-reduce":
        wire = 2 * size * (gsize - 1) / max(gsize, 1)
    elif kind == "all-gather":
        wire = size * (gsize - 1) / max(gsize, 1)
    elif kind == "reduce-scatter":
        wire = size * (gsize - 1)
    elif kind == "all-to-all":
        wire = size * (gsize - 1) / max(gsize, 1)
    else:
        wire = size
    return {"kind": kind, "result_bytes": size, "group": gsize,
            "wire_bytes": wire, "dcn": crosses_pod}


def _op_traffic(op: Op, comps, shapes) -> float:
    """HBM bytes for one materialization-level op.

    Sliced access patterns are honored: an operand consumed through a
    dynamic-slice inside a fusion contributes the SLICE size (a scan
    reading one layer's weights per iteration must not be charged the
    whole stack every iteration), and dynamic-update-slice writes count
    the update size (in-place), not the full buffer.
    """
    inner = op.line.split("(", 1)[1] if "(" in op.line else ""
    operands = [a for a in _OPERAND_RE.findall(inner) if a in shapes]
    if op.kind == "dynamic-slice":
        return 2.0 * op.result_bytes
    if op.kind == "dynamic-update-slice":
        upd = shapes[operands[1]][0] if len(operands) > 1 else op.result_bytes
        return 2.0 * upd
    if op.kind == "fusion":
        cm = re.search(r"calls=%?([\w.\-]+)", op.line)
        target = comps.get(cm.group(1), []) if cm else []
        # positional map: fusion operand k ↔ parameter(k) in the target
        param_names = {}
        for o2 in target:
            pm = re.search(r"parameter\((\d+)\)", o2.line)
            if pm:
                param_names[o2.name] = int(pm.group(1))
        cap = {}          # operand position → capped byte count
        write_bytes = op.result_bytes
        has_dus = False
        for o2 in target:
            in2 = o2.line.split("(", 1)[1] if "(" in o2.line else ""
            args2 = _OPERAND_RE.findall(in2)
            if o2.kind == "dynamic-slice" and args2:
                if args2[0] in param_names:
                    k = param_names[args2[0]]
                    cap[k] = min(cap.get(k, 1 << 62), o2.result_bytes)
            if o2.kind == "dynamic-update-slice" and len(args2) > 1:
                has_dus = True
                upd_b = shapes.get(args2[1], (o2.result_bytes,))[0] \
                    if args2[1] in shapes else o2.result_bytes
                write_bytes = min(write_bytes, upd_b)
        if has_dus:
            # in-place slice update: read update + write slice; the big
            # buffer is aliased, not re-streamed (operand names may pass
            # through converts, so positional caps can't be trusted here)
            return 2.0 * write_bytes
        if any(o2.kind == "dynamic-slice" for o2 in target):
            # slice-reading fusion: streams the slice, not the buffer
            # (same convert-laundered-operand caveat as above)
            return 2.0 * op.result_bytes
        total = write_bytes
        for k, a in enumerate(operands):
            total += min(shapes[a][0], cap.get(k, 1 << 62))
        return float(total)
    return float(sum(shapes[a][0] for a in operands) + op.result_bytes)


def find_entry(txt: str, comps) -> str:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", txt, re.M)
    return m.group(1) if m else next(iter(comps))


def call_graph(comps, entry: str):
    """Walk the module call graph from ``entry``.

    Returns ``(mult, fused, in_loop)``: per-computation loop-trip
    multiplier, whether the computation is only reached through fused
    (traffic-internal) edges, and whether it is reached through a while
    BODY/COND edge (i.e. executes per loop iteration).  Edge kinds:
    fusion/call (×1, mark "fused" so internal traffic is excluded),
    while body+cond (×trip, in-loop), reduce to_apply (×1, tiny),
    branches (×1).  Shared with the graph-invariant linter
    (``repro.analysis.hlo``), which needs the same loop attribution the
    roofline uses.
    """
    mult: Dict[str, float] = {entry: 1.0}
    fused: Dict[str, bool] = {entry: False}
    in_loop: Dict[str, bool] = {entry: False}
    stack = [entry]
    seen = set()
    while stack:
        c = stack.pop()
        if c in seen or c not in comps:
            continue
        seen.add(c)
        m_c = mult.get(c, 1.0)
        looped = in_loop.get(c, False)
        for op in comps[c]:
            if op.kind == "while":
                cm = re.search(r"condition=%?([\w.\-]+)", op.line)
                bm = re.search(r"body=%?([\w.\-]+)", op.line)
                trip = _trip_count(comps.get(cm.group(1), [])) if cm else 1
                for target, tm in ((bm and bm.group(1), m_c * trip),
                                   (cm and cm.group(1), m_c * trip)):
                    if target:
                        mult[target] = max(mult.get(target, 0.0), tm)
                        fused.setdefault(target, False)
                        in_loop[target] = True
                        stack.append(target)
                continue
            targets = _CALL_RE.findall(op.line)
            bm = _BRANCH_RE.search(op.line)
            if bm:
                targets += [t.strip().lstrip("%") for t in bm.group(1).split(",")]
            for t in targets:
                if t == c or t not in comps:
                    continue
                mult[t] = max(mult.get(t, 0.0), m_c)
                in_loop[t] = in_loop.get(t, False) or looped
                is_fusion_call = op.kind in ("fusion",) or "calls=" in op.line
                # to_apply (reduce combiners) treated as fused/internal
                if "to_apply=" in op.line:
                    is_fusion_call = True
                fused[t] = fused.get(t, True) and is_fusion_call \
                    if t in fused else is_fusion_call
                stack.append(t)
    return mult, fused, in_loop


def analyze(txt: str, *, entry: Optional[str] = None,
            pod_size: int = 256) -> Dict[str, Any]:
    comps, shapes = parse_module(txt)
    if entry is None:
        entry = find_entry(txt, comps)
    mult, fused, _ = call_graph(comps, entry)

    flops = 0.0
    hbm = 0.0
    colls: List[Dict[str, Any]] = []
    traffic_top: List[Tuple[float, str]] = []
    for c, ops in comps.items():
        m_c = mult.get(c)
        if m_c is None:
            continue                       # unreachable (dead computation)
        is_fused = fused.get(c, True)
        for op in ops:
            flops += m_c * _dot_flops(op, shapes)
            co = _collective(op, pod_size)
            if co is not None:
                co["wire_bytes"] *= m_c
                co["mult"] = m_c
                colls.append(co)
            # HBM traffic: only at non-fused (materialization) level,
            # skipping pure bookkeeping ops
            # `copy` excluded: on CPU these are loop-carry/layout
            # artifacts of interpret-mode emulation (a 268 MB copy per
            # pallas grid step!); real tensor traffic is charged at the
            # producing/consuming compute ops.
            if not is_fused and op.kind not in (
                    "parameter", "constant", "tuple", "get-tuple-element",
                    "bitcast", "while", "conditional", "after-all",
                    "copy", "copy-start", "copy-done"):
                # ops inside a Pallas kernel region (interpret-mode
                # emulation) are VMEM-resident on real TPU: only the
                # block DMAs (dynamic-slice / dynamic-update-slice —
                # the HBM↔VMEM transfers) count as HBM traffic
                if "pallas_vmem" in op.line and op.kind not in (
                        "dynamic-slice", "dynamic-update-slice", "fusion"):
                    continue
                if "pallas_vmem" in op.line and op.kind == "fusion" \
                        and "dynamic" not in op.line:
                    continue
                t = m_c * _op_traffic(op, comps, shapes)
                hbm += t
                if t > 1e9:
                    meta = re.search(r'op_name="([^"]+)"', op.line)
                    traffic_top.append(
                        (t, f"{op.kind} x{m_c:.0f} "
                            f"{(meta.group(1)[:70] if meta else op.name)}"))

    agg: Dict[str, float] = {}
    for o in colls:
        agg[o["kind"]] = agg.get(o["kind"], 0.0) + o["wire_bytes"]
    traffic_top.sort(key=lambda t: -t[0])
    return {
        "flops": flops,
        "hbm_bytes": hbm,
        "traffic_top": [{"bytes": t, "op": d} for t, d in traffic_top[:20]],
        "collectives": {
            "bytes_by_kind": agg,
            "total_wire_bytes": sum(o["wire_bytes"] for o in colls),
            "dcn_wire_bytes": sum(o["wire_bytes"] for o in colls if o["dcn"]),
            "count": len(colls),
            "top_ops": sorted(colls, key=lambda o: -o["wire_bytes"])[:20],
        },
    }
