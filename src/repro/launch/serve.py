"""Serving driver: prefill a batch of prompts, decode with batched steps.

  PYTHONPATH=src python -m repro.launch.serve --arch dbrx-132b --smoke \\
      --batch 4 --prompt-len 64 --gen 32 --dispatch grouped

``--dispatch {sort,grouped}`` selects the MoE decode dispatch mode
(validated against ``DISPATCH_MODES`` — a typo fails fast, it never
silently falls back); ``grouped`` is the supported serving
configuration for MoE archs (dropless grouped compute on the tiny,
latency-bound decode batches).  The compiled prefill/decode steps come
from the ``serving/engine.py`` step-builder cache.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import tuning
from repro.launch import mesh as mesh_lib
from repro.models import transformer as T
from repro.serving import generate
from repro.serving.engine import serve_config, validate_dispatch


def dispatch_cli_arg(name: str):
    """argparse ``type=`` adapter for :func:`validate_dispatch`
    (argparse prints ArgumentTypeError messages verbatim; bare
    ValueError it swallows — same pattern as ``mesh_cli_arg``)."""
    try:
        return validate_dispatch(name)
    except ValueError as e:
        raise argparse.ArgumentTypeError(str(e))


def run(arch: str, *, smoke: bool, batch: int, prompt_len: int, gen: int,
        mesh_shape=(1, 1), temperature: float = 0.0, seed: int = 0,
        dispatch=None, tune: str = "auto", fabric=None):
    cfg = configs.smoke_config(arch) if smoke else configs.get_config(arch)
    assert cfg.has_decode, f"{arch} is encoder-only"
    cfg = serve_config(cfg, dispatch=dispatch)
    mesh = mesh_lib.make_smoke_mesh(tuple(mesh_shape))
    tmode, tfab = tuning.configure(tune, fabric, mesh=mesh)
    if cfg.moe is not None:
        print(f"dispatch={cfg.moe.dispatch} "
              f"({'flag' if dispatch else 'config default'}) "
              f"tune={tmode} fabric={tfab}")
    rng = jax.random.PRNGKey(seed)
    params = T.init_model(rng, cfg)
    if cfg.frontend is None:
        prompt = jax.random.randint(rng, (batch, prompt_len), 0, cfg.vocab_size)
    else:
        raise SystemExit(f"{arch}: serve driver takes token prompts; "
                         f"frontend archs are served via the API directly")
    t0 = time.time()
    out = generate(params, cfg, prompt, steps=gen, mesh=mesh,
                   temperature=temperature, rng=rng)
    dt = time.time() - t0
    print(f"arch={cfg.name} batch={batch} prompt={prompt_len} gen={gen} "
          f"-> {out.shape} in {dt:.2f}s ({batch * gen / dt:.1f} tok/s)")
    print("sample continuation ids:", out[0, prompt_len:prompt_len + 16].tolist())
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--mesh", default="1x1", type=mesh_lib.mesh_cli_arg)
    ap.add_argument("--dispatch", default=None, type=dispatch_cli_arg,
                    help="MoE decode dispatch mode override "
                         "(sort|grouped; validated, no silent fallback)")
    ap.add_argument("--tune", default="auto",
                    choices=list(tuning.TUNE_MODES),
                    help="'auto' resolves MoEConfig 'auto' knobs from the "
                         "α–β cost model, 'off' pins the static defaults, "
                         "'calibrate' fits α–β from measured AllToAlls "
                         "(persisted to TUNE_moe.json)")
    ap.add_argument("--fabric", default="ici_dcn",
                    type=mesh_lib.fabric_cli_arg,
                    help="named fast/slow LinkSpec pair the tuner scores "
                         "against (ici_dcn | pcie_eth100)")
    args = ap.parse_args()
    run(args.arch, smoke=args.smoke, batch=args.batch,
        prompt_len=args.prompt_len, gen=args.gen,
        temperature=args.temperature, mesh_shape=args.mesh,
        dispatch=args.dispatch, tune=args.tune, fabric=args.fabric)


if __name__ == "__main__":
    main()
