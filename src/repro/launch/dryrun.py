import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) and
extract roofline terms — NO real allocation (ShapeDtypeStruct stand-ins).

The two lines above MUST precede any other import (jax locks the device
count at first init); smoke tests / benches import other modules and see
1 device.

Per pair this produces a JSON record in experiments/dryrun/:
  memory_analysis   bytes/device (args, temps, output, aliased)
  cost_analysis     per-device HLO FLOPs + bytes accessed
  collectives       per-op kind / wire bytes / group size, parsed from
                    the compiled HLO (cost_analysis has no collectives)
  roofline          the three terms in seconds + dominant bottleneck
                    (v5e: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
                    ICI, DCN for pod-crossing groups)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch dbrx-132b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import re
import time
from typing import Any, Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.core.config import INPUT_SHAPES, TrainConfig
from repro.data.pipeline import make_batch_specs
from repro.launch import hlo_analysis
from repro.launch import mesh as mesh_lib
from repro.models import transformer as T
from repro.serving.engine import make_prefill_step, make_serve_step
from repro.training.train_step import init_train_state, make_train_step

# ---------------------------------------------------------------------------
# hardware constants (TPU v5e)
# ---------------------------------------------------------------------------
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link (per-chip effective, one direction)
DCN_BW = 6.25e9              # B/s / chip across pods

# gemma2 runs long_500k as the documented capped-global-window variant
LONG_CONTEXT_VARIANT = {"gemma2-9b"}

# dry-run training defaults: block remat + f32 master/moments
DRYRUN_TCFG = TrainConfig(remat="block", microbatches=1)
# the giant MoE config needs bf16 moments to fit 16 GB/chip (EXPERIMENTS.md)
DRYRUN_TCFG_GIANT = TrainConfig(remat="block", microbatches=1,
                                optimizer_state_dtype="bfloat16")
GIANT = {"llama4-maverick-400b-a17b"}


def eligible(arch: str, shape_name: str) -> Optional[str]:
    """None if the pair runs; otherwise the skip reason (DESIGN.md §skips)."""
    cfg = configs.get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if shape.mode == "decode" and not cfg.has_decode:
        return "encoder-only: no decode step"
    if shape_name == "long_500k":
        if not cfg.has_decode:
            return "encoder-only: no decode step"
        if not (cfg.is_subquadratic or arch in LONG_CONTEXT_VARIANT):
            return "pure full-attention: 524k dense-KV decode not faked"
    return None


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1}
_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\S+))\s+"
    r"(all-reduce|all-gather|all-to-all|reduce-scatter|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|pred)"
                       r"\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str, pod_size: int = 256) -> Dict[str, Any]:
    """Per-device wire bytes per collective kind (ring-algorithm costs)."""
    ops = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(3)
        result = m.group(1) or m.group(2) or ""
        size = _shape_bytes(result)
        gm = _GROUPS_RE.search(line)
        gsize = int(gm.group(2)) if gm else 1
        # does any group cross the pod boundary? (iota pattern heuristic:
        # explicit long lists are rare; check '<=[2,' leading pod dim usage)
        crosses_pod = False
        im = re.search(r"<=\[([0-9,]+)\]", line)
        if im:
            iota_dims = [int(x) for x in im.group(1).split(",")]
            total = int(np.prod(iota_dims))
            if total > pod_size and gsize > 1:
                # conservative: a group spans pods if group elements stride
                # beyond one pod — flag when the group covers dims that
                # include the leading (pod) axis
                ngroups = int(gm.group(1)) if gm else 1
                crosses_pod = ngroups * gsize > pod_size and \
                    total // iota_dims[0] < gsize * ngroups
        if kind == "all-reduce":
            wire = 2 * size * (gsize - 1) / max(gsize, 1)
        elif kind == "all-gather":
            wire = size * (gsize - 1) / max(gsize, 1)
        elif kind == "reduce-scatter":
            wire = size * (gsize - 1)
        elif kind == "all-to-all":
            wire = size * (gsize - 1) / max(gsize, 1)
        else:  # collective-permute
            wire = size
        ops.append({"kind": kind, "result_bytes": size, "group": gsize,
                    "wire_bytes": wire, "dcn": bool(crosses_pod)})
    agg: Dict[str, float] = {}
    for o in ops:
        agg[o["kind"]] = agg.get(o["kind"], 0.0) + o["wire_bytes"]
    return {"ops": ops, "bytes_by_kind": agg,
            "total_wire_bytes": sum(o["wire_bytes"] for o in ops),
            "dcn_wire_bytes": sum(o["wire_bytes"] for o in ops if o["dcn"]),
            "count": len(ops)}


# ---------------------------------------------------------------------------
# model-FLOPs estimate (6·N·D with N = active params)
# ---------------------------------------------------------------------------

def active_params(cfg) -> float:
    """Parameter count, counting only top-k + shared experts of MoE layers."""
    d, f, V = cfg.d_model, cfg.d_ff, cfg.vocab_size
    total = V * d * (1 if cfg.tie_embeddings else 2) if cfg.frontend is None \
        else V * d
    per = {"glu": 3 * d * f, "plain": 2 * d * f}
    mlp_p = per["glu"] if cfg.act in ("swiglu", "geglu") else per["plain"]
    attn_p = 0
    if cfg.attention is not None:
        hd = cfg.head_dim
        a = cfg.attention
        attn_p = d * hd * (a.num_heads * 2 + a.num_kv_heads * 2)
    for kind in cfg.block_pattern:
        n = cfg.num_layers // len(cfg.block_pattern)
        if kind in ("attn", "local", "global", "dense"):
            total += n * (attn_p + mlp_p)
        elif kind == "moe":
            fe = cfg.moe.d_ff_expert or f
            e_p = (3 if cfg.act in ("swiglu", "geglu") else 2) * d * fe
            from repro.core import gating
            k = gating.gate_k(cfg.moe)
            total += n * (attn_p + (k + cfg.moe.num_shared_experts) * e_p
                          + d * cfg.moe.num_experts)
        elif kind in ("mamba", "mamba_sa"):
            d_in = cfg.ssm.expand * d
            H = d_in // cfg.ssm.head_dim
            total += n * (d * (2 * d_in + 2 * cfg.ssm.n_groups * cfg.ssm.d_state + H)
                          + d_in * d)
            if kind == "mamba_sa":
                total += n * (d * 2 * 16)       # lora only; shared attn once
        elif kind == "rwkv":
            total += n * (5 * d * d + mlp_p)
    if "mamba_sa" in cfg.block_pattern:
        total += attn_p
    return float(total)


def attention_flops_fwd(cfg, shape) -> float:
    """Forward attention-matmul FLOPs (QKᵀ + PV): 4·tokens·S_ctx·H·hd.
    S_ctx: causal average S/2 for full attention, the window for SWA
    layers, the full cache length for decode."""
    if cfg.attention is None:
        return 0.0
    tokens = shape.global_batch * (shape.seq_len if shape.mode != "decode" else 1)
    H, hd = cfg.attention.num_heads, cfg.head_dim
    total = 0.0
    per = cfg.num_layers // len(cfg.block_pattern)
    for kind in cfg.block_pattern:
        if kind in ("mamba", "rwkv"):
            continue
        win = cfg.local_window if kind == "local" else cfg.attention.window
        if shape.mode == "decode":
            ctx = min(shape.seq_len, win) if win else shape.seq_len
        else:
            ctx = min(shape.seq_len, win) if win else shape.seq_len / 2
        n = per if kind != "mamba_sa" else per
        total += n * 4.0 * tokens * ctx * H * hd
    return total


def model_flops(cfg, shape) -> float:
    """Param term (6·N_active·D train / 2·N·D inference) + attention term
    (3×fwd for train — bwd counts double)."""
    tokens = shape.global_batch * (shape.seq_len if shape.mode != "decode" else 1)
    mult = 6.0 if shape.mode == "train" else 2.0
    attn_mult = 3.0 if shape.mode == "train" else 1.0
    return (mult * active_params(cfg) * tokens
            + attn_mult * attention_flops_fwd(cfg, shape))


# ---------------------------------------------------------------------------
# lowering per mode
# ---------------------------------------------------------------------------

def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def input_specs(arch: str, shape_name: str, mesh, *, a2a: str = None,
                dispatch: str = None):
    """ShapeDtypeStruct stand-ins for every model input of this pair."""
    cfg = _cfg_with_overrides(arch, a2a=a2a, dispatch=dispatch)
    shape = INPUT_SHAPES[shape_name]
    if shape.mode == "train":
        batch = make_batch_specs(cfg, shape, dtype=cfg.dtype)
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, s.dtype, sharding=mesh_lib.batch_shardings(
                    mesh, {"x": s})["x"]), batch)
    B = shape.global_batch
    dp = mesh_lib.dp_axes(mesh)

    def _tok(shape_, dtype_):
        sh = mesh_lib.fit_spec(mesh, P(dp), shape_)
        return jax.ShapeDtypeStruct(shape_, dtype_, sharding=sh)

    if shape.mode == "prefill":
        if cfg.frontend is not None:
            return _tok((B, shape.seq_len, cfg.d_model), jnp.dtype(cfg.dtype))
        return _tok((B, shape.seq_len), jnp.int32)
    # decode: one token + caches
    if cfg.frontend is not None:
        tok = _tok((B, 1, cfg.d_model), jnp.dtype(cfg.dtype))
    else:
        tok = _tok((B, 1), jnp.int32)
    long_ctx = shape_name == "long_500k"
    cache_shapes = jax.eval_shape(
        lambda: T.init_caches(cfg, B, shape.seq_len, long_context=long_ctx,
                              dtype=jnp.dtype(cfg.dtype)))
    shardings = mesh_lib.cache_shardings(mesh, cache_shapes)
    caches = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        cache_shapes, shardings)
    return tok, caches


def _cfg_with_overrides(arch, *, a2a=None, dispatch=None, capacity=None):
    import dataclasses
    cfg = configs.get_config(arch)
    if cfg.moe is not None and (a2a or dispatch or capacity):
        kw = {}
        if a2a:
            kw["a2a"] = a2a
        if dispatch:
            kw["dispatch"] = dispatch
        if capacity:
            kw["capacity_factor"] = capacity
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, **kw))
    return cfg


def lower_pair(arch: str, shape_name: str, mesh, *, a2a=None, dispatch=None,
               tcfg: TrainConfig = None):
    """Build + .lower() the step function for one (arch, shape, mesh)."""
    cfg = _cfg_with_overrides(arch, a2a=a2a, dispatch=dispatch)
    shape = INPUT_SHAPES[shape_name]
    long_ctx = shape_name == "long_500k"
    if shape.mode == "train":
        tcfg = tcfg or (DRYRUN_TCFG_GIANT if arch in GIANT else DRYRUN_TCFG)
        state_shapes = jax.eval_shape(
            lambda r: init_train_state(r, cfg, tcfg), jax.random.key(0))
        state = jax.tree.map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            state_shapes, mesh_lib.state_shardings(mesh, state_shapes))
        batch = input_specs(arch, shape_name, mesh, a2a=a2a, dispatch=dispatch)
        rng = jax.ShapeDtypeStruct((2,), jnp.uint32,
                                   sharding=NamedSharding(mesh, P()))
        fn = make_train_step(cfg, tcfg, mesh)

        def step(state, batch, rng_raw):
            return fn(state, batch, jax.random.wrap_key_data(rng_raw))

        return jax.jit(step, donate_argnums=(0,)).lower(state, batch, rng)
    # inference params (no optimizer state) — served in the model compute
    # dtype (bf16); the router weight stays f32 (gating numerics)
    params_shapes = jax.eval_shape(lambda r: T.init_model(r, cfg),
                                   jax.random.key(0))
    serve_dt = jnp.dtype(cfg.dtype)

    def _serve_cast(path, s):
        name = str(getattr(path[-1], "key", ""))
        if s.dtype == jnp.float32 and name != "gate_w":
            return jax.ShapeDtypeStruct(s.shape, serve_dt)
        return s

    params_shapes = jax.tree_util.tree_map_with_path(_serve_cast, params_shapes)
    fsdp = mesh_lib.needs_fsdp(mesh, params_shapes, budget_bytes=4e9)
    etp = (shape.mode == "decode" and cfg.moe is not None
           and os.environ.get("REPRO_EXPERT_TP", "1") == "1")
    params = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        params_shapes, mesh_lib.param_shardings(mesh, params_shapes, fsdp=fsdp,
                                                expert_tp=etp))
    if shape.mode == "prefill":
        tokens = input_specs(arch, shape_name, mesh, a2a=a2a, dispatch=dispatch)
        if cfg.has_decode:
            fn = make_prefill_step(cfg, mesh, cache_len=shape.seq_len)
        else:
            def fn(params, tokens):       # encoder: full forward, no cache
                h, aux, _ = T.forward(params, tokens, cfg, mesh=mesh)
                return T.logits_from_hidden(params, cfg, h, mesh)
        return jax.jit(fn).lower(params, tokens)
    # decode
    tok, caches = input_specs(arch, shape_name, mesh, a2a=a2a, dispatch=dispatch)
    fn = make_serve_step(cfg, mesh, long_context=long_ctx)
    return jax.jit(fn, donate_argnums=(2,)).lower(params, tok, caches)


# ---------------------------------------------------------------------------
# roofline
# ---------------------------------------------------------------------------

def roofline(record: Dict[str, Any], mesh_shape, arch, shape_name) -> Dict:
    cfg = configs.get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    chips = int(np.prod([v for v in mesh_shape.values()]))
    ha = record["hlo_analysis"]
    flops_dev = ha["flops"]                       # per-device, loop-corrected
    bytes_dev = ha["hbm_bytes"]
    coll = record["collectives"]
    ici_bytes = coll["total_wire_bytes"] - coll["dcn_wire_bytes"]
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = ici_bytes / ICI_BW + coll["dcn_wire_bytes"] / DCN_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_total = flops_dev * chips
    return {
        **terms,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_total": hlo_total,
        "useful_fraction": mf / hlo_total if hlo_total else 0.0,
        "step_time_bound_s": max(terms.values()),
        "chips": chips,
    }


def run_pair(arch: str, shape_name: str, *, multi_pod: bool = False,
             a2a=None, dispatch=None, tag: str = "", save: bool = True,
             tcfg: TrainConfig = None) -> Dict[str, Any]:
    reason = eligible(arch, shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "a2a": a2a, "dispatch": dispatch}
    if reason is not None:
        rec["skipped"] = reason
        if save:
            _save(rec, tag)
        return rec
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    lowered = lower_pair(arch, shape_name, mesh, a2a=a2a, dispatch=dispatch,
                         tcfg=tcfg)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    ma = compiled.memory_analysis()
    rec["memory_analysis"] = {
        "argument_bytes": ma.argument_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "alias_bytes": ma.alias_size_in_bytes,
        "peak_per_device_bytes": (ma.argument_size_in_bytes
                                  + ma.temp_size_in_bytes
                                  + ma.output_size_in_bytes
                                  - ma.alias_size_in_bytes),
    }
    # XLA's cost_analysis counts while-loop bodies once — kept for
    # reference only; the roofline uses the loop-corrected HLO analyzer.
    rec["cost_analysis_raw"] = {
        k: v for k, v in compiled.cost_analysis().items()
        if k in ("flops", "bytes accessed")}
    ha = hlo_analysis.analyze(compiled.as_text())
    rec["hlo_analysis"] = {"flops": ha["flops"], "hbm_bytes": ha["hbm_bytes"],
                           "traffic_top": ha["traffic_top"]}
    rec["collectives"] = ha["collectives"]
    rec["roofline"] = roofline(rec, dict(mesh.shape), arch, shape_name)
    rec["lower_s"] = round(t1 - t0, 2)
    rec["compile_s"] = round(t2 - t1, 2)
    if save:
        _save(rec, tag)
    return rec


def _save(rec, tag=""):
    d = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                     "experiments", "dryrun")
    os.makedirs(d, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}"
    if rec.get("a2a"):
        name += f"__a2a-{rec['a2a']}"
    if rec.get("dispatch"):
        name += f"__disp-{rec['dispatch']}"
    if tag:
        name += f"__{tag}"
    with open(os.path.join(d, name + ".json"), "w") as f:
        json.dump(rec, f, indent=1, default=float)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--a2a", default=None, choices=[None, "flat", "hierarchical"])
    ap.add_argument("--dispatch", default=None, choices=[None, "sort", "dense"])
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    pairs = []
    if args.all:
        for a in configs.ASSIGNED:
            for s in INPUT_SHAPES:
                pairs.append((a, s))
    else:
        assert args.arch and args.shape
        pairs = [(args.arch, args.shape)]
    for a, s in pairs:
        rec = run_pair(a, s, multi_pod=args.multi_pod, a2a=args.a2a,
                       dispatch=args.dispatch, tag=args.tag)
        if "skipped" in rec:
            print(f"{a:28s} {s:12s} SKIP: {rec['skipped']}")
        else:
            r = rec["roofline"]
            print(f"{a:28s} {s:12s} {rec['mesh']:8s} "
                  f"compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s "
                  f"coll={r['collective_s']:.3e}s dom={r['dominant']:10s} "
                  f"mem/dev={rec['memory_analysis']['peak_per_device_bytes']/2**30:.2f}GiB "
                  f"(lower {rec['lower_s']}s compile {rec['compile_s']}s)")


if __name__ == "__main__":
    main()
