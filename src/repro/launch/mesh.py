"""Production mesh + parameter/batch sharding rules.

Mesh: ``(data=16, model=16)`` single pod (256 v5e chips) or
``(pod=2, data=16, model=16)`` for the 2-pod 512-chip run.  Constructed
by a FUNCTION so importing this module never touches jax device state.

Sharding policy (DESIGN.md §4):
  batch            → (pod, data)
  experts          → model  (expert parallelism; the AllToAll axis)
  expert weights   → additionally FSDP-shard d_model over data; the
                     shard_map in_spec P(model, None, None) makes XLA
                     all-gather them per layer (ZeRO-3) and reduce-
                     scatter the gradients automatically
  attention heads / FFN hidden → model (tensor parallelism)
  dense weights    → additionally FSDP over data
  vocab (embed + lm_head + logits) → model
  norms / small vectors → replicated
"""
from __future__ import annotations

from typing import Any, Tuple

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    assert len(devs) >= n, (
        f"need {n} devices, have {len(devs)} — the dry-run entrypoint must "
        f"set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
        f"any jax import")
    return Mesh(np.asarray(devs[:n]).reshape(shape), axes)


def make_smoke_mesh(shape: Tuple[int, ...] = (1, 1),
                    axes: Tuple[str, ...] = ("data", "model")) -> Mesh:
    n = int(np.prod(shape))
    return Mesh(np.asarray(jax.devices()[:n]).reshape(shape), axes)


def parse_mesh(spec: str) -> Tuple[int, ...]:
    """Parse a ``--mesh`` string like ``1x1`` / ``16x16`` into a shape
    tuple, with a clear error for typos (``16x``, ``axb``, ``0x4``)."""
    parts = str(spec).split("x")
    try:
        dims = tuple(int(p) for p in parts)
    except ValueError:
        dims = ()
    if not dims or any(d < 1 for d in dims):
        raise ValueError(
            f"--mesh expects 'DxM' with positive integers (e.g. '1x1', "
            f"'16x16', or '2x16x16' for multi-pod), got {spec!r}")
    return dims


def mesh_cli_arg(spec: str):
    """argparse ``type=`` adapter for :func:`parse_mesh` (argparse prints
    ArgumentTypeError messages verbatim; bare ValueError it swallows)."""
    import argparse
    try:
        return parse_mesh(spec)
    except ValueError as e:
        raise argparse.ArgumentTypeError(str(e))


def parse_fabric(name: str):
    """Parse a ``--fabric`` name into ``(name, (fast, slow))`` — a named
    ``LinkSpec`` pair from ``core/alltoall.FABRICS`` (``ici_dcn``,
    ``pcie_eth100``).  The pair feeds the auto-tuner's α–β scoring
    (``core/tuning.py``) and the cost-model benchmarks; a typo raises a
    ValueError listing the valid fabrics (same convention as
    :func:`parse_mesh`)."""
    from repro.core import alltoall
    key = str(name).strip().lower()
    if key not in alltoall.FABRICS:
        raise ValueError(
            f"--fabric expects one of {tuple(alltoall.FABRICS)} (named "
            f"fast/slow LinkSpec pairs in core/alltoall.py), got {name!r}")
    return key, alltoall.FABRICS[key]


def fabric_cli_arg(name: str):
    """argparse ``type=`` adapter for :func:`parse_fabric` (mirrors
    :func:`mesh_cli_arg`)."""
    import argparse
    try:
        return parse_fabric(name)
    except ValueError as e:
        raise argparse.ArgumentTypeError(str(e))


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


# ---------------------------------------------------------------------------
# parameter sharding rules (path + ndim → PartitionSpec)
# ---------------------------------------------------------------------------

# trailing-dim specs keyed by leaf name; a leading None is prepended for
# the scan (super-block) dimension of leaves under "blocks/".
_RULES = {
    # embeddings / head
    "embed":   ("model", "data"),
    "lm_head": ("data", "model"),
    # attention
    "wq": ("data", "model"), "wk": ("data", "model"), "wv": ("data", "model"),
    "wo": ("model", "data"),
    # mlp (and rwkv channel-mix)
    "w_in_mlp":  ("data", "model"),
    "w_out_mlp": ("model", "data"),
    # moe experts: (E, d, f) / (E, f, d) — EP over model + FSDP(d) over data
    "w_up_moe":   ("model", "data", None),
    "w_gate_moe": ("model", "data", None),
    "w_out_moe":  ("model", None, "data"),
    # expert-TP serving layout (decode): f dim over data, zero-reshard
    # against the shard_map in_specs of moe_block_local's TP mode
    "w_up_moe_tp":   ("model", None, "data"),
    "w_gate_moe_tp": ("model", None, "data"),
    "w_out_moe_tp":  ("model", "data", None),
    "gate_w": (None, None),
    # mamba2
    "w_in_mamba":  ("data", "model"),
    "w_out_mamba": ("model", "data"),
    "conv_w": (None, "model"), "conv_b": ("model",),
    # rwkv6
    "wr": ("data", "model"), "wg": ("data", "model"),
    "mix_a": ("data", None), "decay_a": ("data", None),
    # zamba2 lora
    "sa_lora_a": ("data", None), "sa_lora_b": (None, "data"),
}


def _leaf_spec(path: str, ndim: int, expert_tp: bool = False) -> P:
    parts = path.split("/")
    name = parts[-1]
    in_blocks = parts[0] == "blocks"
    parent = parts[-2] if len(parts) > 1 else ""
    key = name
    if name in ("w_in", "w_out", "w_up", "w_gate"):
        if parent == "moe":
            key = f"{name}_moe" + ("_tp" if expert_tp else "")
        elif parent == "mamba":
            key = f"{name}_mamba"
        else:
            key = f"{name}_mlp"
    dims = _RULES.get(key)
    if dims is None:
        dims = ()                       # replicate (norms, biases, vectors)
    spec: Tuple[Any, ...] = tuple(dims)
    lead = ndim - len(spec)
    assert lead >= 0, (path, ndim, spec)
    return P(*((None,) * lead + spec))


def fit_spec(mesh: Mesh, spec: P, shape) -> NamedSharding:
    """Drop spec axes that don't exist in the mesh or don't divide the
    dimension (e.g. vocab 92553 on a 16-wide axis, batch 1 on data)."""
    dims = []
    for i, s in enumerate(tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))):
        if s is None:
            dims.append(None)
            continue
        axes = s if isinstance(s, tuple) else (s,)
        axes = tuple(a for a in axes if a in mesh.axis_names)
        n = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
        if n <= 1 or shape[i] % n != 0:
            dims.append(None)
        else:
            dims.append(axes if len(axes) > 1 else axes[0])
    return NamedSharding(mesh, P(*dims))


def _tree_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        yield key, leaf
    return


def needs_fsdp(mesh: Mesh, params_shapes, *, budget_bytes: float = 6e9) -> bool:
    """FSDP-shard weights over data iff master+moments (12 B/param) would
    exceed ``budget_bytes`` per device under model-axis sharding alone."""
    total = sum(np.prod(l.shape) for l in jax.tree.leaves(params_shapes))
    per_dev = total * 12.0 / mesh.shape.get("model", 1)
    return per_dev > budget_bytes


def param_shardings(mesh: Mesh, params_shapes, *, fsdp: bool = True,
                    expert_tp: bool = False) -> Any:
    """Tree of NamedShardings matching a params (or m/v moments) tree.

    ``fsdp=False`` drops the data-axis (ZeRO) sharding — pure TP+replica —
    which avoids per-use weight all-gathers for models that fit.
    ``expert_tp=True`` stores expert weights in the serving (decode)
    layout: f over data, matching moe_block_local's TP in_specs."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        spec = _leaf_spec(key, len(leaf.shape), expert_tp)
        if not fsdp and not (expert_tp and "/moe/" in key):
            spec = P(*(None if s == "data" else s for s in tuple(spec)))
        out.append(fit_spec(mesh, spec, leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, out)


def state_shardings(mesh: Mesh, state_shapes, *, fsdp: bool = None) -> Any:
    """Shardings for a TrainState: params/moments per the rules, every
    other field (step + the fault-tolerance scalars) replicated."""
    if fsdp is None:
        fsdp = needs_fsdp(mesh, state_shapes.params)
    p = param_shardings(mesh, state_shapes.params, fsdp=fsdp)
    repl = NamedSharding(mesh, P())
    scalars = {f: (None if getattr(state_shapes, f) is None else repl)
               for f in type(state_shapes)._fields
               if f not in ("params", "opt")}
    return type(state_shapes)(
        params=p,
        opt={"m": param_shardings(mesh, state_shapes.opt["m"], fsdp=fsdp),
             "v": param_shardings(mesh, state_shapes.opt["v"], fsdp=fsdp),
             "count": repl},
        **scalars)


def batch_shardings(mesh: Mesh, batch_shapes) -> Any:
    """Batch dim → (pod, data); everything else replicated."""
    dp = dp_axes(mesh)
    return jax.tree.map(
        lambda s: fit_spec(mesh, P(dp), s.shape), batch_shapes)


def cache_shardings(mesh: Mesh, cache_shapes) -> Any:
    """Decode caches: leaves are (NSB, B, ...) — batch dim → (pod, data);
    kv-head / ssm-head dims → model where divisible."""
    dp = dp_axes(mesh)
    msize = mesh.shape.get("model", 1)

    def spec(leaf):
        shp = leaf.shape
        if len(shp) <= 1:                    # pos scalars per super-block
            return NamedSharding(mesh, P())
        dims = [None, dp] + [None] * (len(shp) - 2)   # (NSB, B, ...)
        # shard ONE inner axis over model.  Preference order: the
        # kv/ssm-head axis (dim -2: TP-style, no gather at decode), else
        # the cache-seq / state axis (dim 2: memory-balanced, XLA
        # gathers per layer), else the channel axis (dim -1).
        if msize > 1 and len(shp) >= 4:
            for cand in (len(shp) - 2, 2, len(shp) - 1):
                if cand >= 2 and shp[cand] % msize == 0:
                    dims[cand] = "model"
                    break
        return fit_spec(mesh, P(*dims), shp)

    return jax.tree.map(spec, cache_shapes)
