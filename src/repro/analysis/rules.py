"""The rule registry and the shipped graph-invariant rules.

Every rule encodes a hazard this repo has ALREADY hit (the PR number is
the regression it guards), expressed over the structured walkers in
``analysis.graph`` / ``analysis.hlo`` instead of jaxpr substring greps:

  collective-in-loop      PR 5: ``lax.scan`` folded the P pipelined
                          exchanges into ONE loop-body collective,
                          hiding the overlap from XLA's scheduler.
  overlap-chunk-count     PR 5: the pipeline must emit exactly 3P flat /
                          5P hierarchical all-to-alls with (M, B/P, d)
                          payload windows for ``overlap_chunks = P``.
  tuned-plan-consistency  PR 9: a graph traced under an "auto"-knob
                          config must carry exactly the AllToAll
                          count/payload windows of the TunedPlan
                          ``core/tuning.py`` resolves for that cell —
                          "auto" must never silently change a traced
                          graph shape.
  no-recompute-backward   PR 3: the grouped backward must run the Pallas
                          dlhs/drhs kernels off the residuals — a
                          ``ragged_dot`` in a grad graph is the VJP
                          re-running the whole forward.
  dtype-leak              PR 4: ``ragged_dot``'s transpose leaked f32
                          cotangents into bf16 dots (f32 compute, 2×
                          bytes) — mixed float operand dtypes on a
                          dot-like equation mean a missing cast.
  payload-dtype           PR 10: the grouped exchange's payload
                          AllToAlls must move the RESOLVED wire dtype
                          (int8/fp8 when ``payload_dtype`` is set, the
                          compute dtype otherwise), and no quantized
                          wire dtype may reach a dot-like equation —
                          dequantization happens between the exchange
                          and the matmul, never inside it.
  donation-alias          PR 6: donated ``TrainState`` leaves sharing a
                          buffer make XLA donation reject the alias.
  retrace-budget          PR 7: each serving step-builder key traces
                          once; more means a compiled-step cache leak.
  config-invalid          a config × mesh cell the validators reject
                          (``moe.validate_dispatch_config`` /
                          ``engine.validate_decode_config``) — the lint
                          CLI reports the rejection as a finding instead
                          of dying on a traceback.

New-graph-invariant convention (ROADMAP process note): a new rule ships
with a KNOWN-BAD case in ``tests/test_analysis.py`` that makes it fire,
plus the clean config matrix proving it stays quiet on healthy graphs.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.analysis.graph import EqnSite, JaxprGraph, ProbeGraph

LEVELS = ("error", "warn", "info")

# jaxpr primitive names that move data across mesh ranks (the psum-like
# reductions included: any of these inside a loop body serializes the
# pipeline the same way)
COLLECTIVE_PRIMITIVES = frozenset({
    "all_to_all", "all_gather", "all_gather_invariant", "psum",
    "psum_invariant", "psum_scatter", "reduce_scatter", "ppermute",
    "pgather", "pmax", "pmin",
})

# dot-like primitives whose operand dtypes must agree (group_sizes /
# index operands are integral and exempt)
DOT_PRIMITIVES = frozenset({"dot_general", "ragged_dot"})


@dataclass(frozen=True)
class Finding:
    """One lint hit.  ``location`` is a structural path
    (``shard_map/scan/all_to_all``) or a probe key; ``config`` is the
    matrix cell / graph label it was found under."""
    rule: str
    level: str
    location: str
    message: str
    config: str = ""

    def as_dict(self) -> Dict[str, str]:
        return {"rule": self.rule, "level": self.level,
                "location": self.location, "message": self.message,
                "config": self.config}


@dataclass(frozen=True)
class Rule:
    name: str
    level: str
    kinds: Tuple[str, ...]                  # graph kinds it applies to
    check: Callable[[Any], List[Finding]]   # Graph -> findings
    doc: str = ""


REGISTRY: Dict[str, Rule] = {}


def register(name: str, level: str, kinds: Tuple[str, ...]):
    """Decorator: register ``check(graph) -> [Finding]`` under ``name``.

    The wrapped checker may return ``Finding`` dicts without ``rule`` /
    ``level`` filled; they are stamped here so a rule cannot misreport
    its own identity.
    """
    if level not in LEVELS:
        raise ValueError(f"rule {name!r}: level must be one of {LEVELS}, "
                         f"got {level!r}")

    def wrap(fn: Callable) -> Callable:
        def check(graph) -> List[Finding]:
            out = []
            for f in fn(graph):
                if isinstance(f, Finding):
                    out.append(Finding(name, level, f.location, f.message,
                                       f.config or graph.label))
                else:  # (location, message) shorthand
                    loc, msg = f
                    out.append(Finding(name, level, loc, msg, graph.label))
            return out
        REGISTRY[name] = Rule(name, level, kinds, check, doc=fn.__doc__ or "")
        return fn
    return wrap


def rules_for(kind: str, names: Optional[Iterable[str]] = None) -> List[Rule]:
    wanted = set(names) if names is not None else None
    unknown = (wanted or set()) - set(REGISTRY)
    if unknown:
        raise ValueError(f"unknown lint rule(s) {sorted(unknown)}; "
                         f"registered: {sorted(REGISTRY)}")
    return [r for r in REGISTRY.values()
            if kind in r.kinds and (wanted is None or r.name in wanted)]


def run_rule(name: str, graph) -> List[Finding]:
    """Run ONE registered rule against a graph (the test-suite entry
    point for porting the old substring witnesses)."""
    if name not in REGISTRY:
        raise ValueError(f"unknown lint rule {name!r}; "
                         f"registered: {sorted(REGISTRY)}")
    return REGISTRY[name].check(graph)


def lint_graph(graph, rules: Optional[Iterable[str]] = None) -> List[Finding]:
    out: List[Finding] = []
    for rule in rules_for(graph.kind, rules):
        out.extend(rule.check(graph))
    return out


# ---------------------------------------------------------------------------
# shipped rules — jaxpr side
# ---------------------------------------------------------------------------

@register("collective-in-loop", "error", ("jaxpr", "hlo"))
def _collective_in_loop(graph) -> List:
    """A cross-rank collective inside a ``scan``/``while`` BODY.  XLA
    schedules one loop iteration at a time, so a collective folded into
    a loop body cannot overlap the next iteration's compute — exactly
    how the PR 5 pipeline silently lost its overlap when written as a
    ``fori_loop``.  The dispatch graphs this linter traces unroll every
    pipelined exchange statically; a per-layer scan over super-blocks is
    a different (whole-model) graph and is not linted by the matrix.
    """
    if graph.context.get("allow_loop_collectives"):
        return []
    out = []
    if graph.kind == "hlo":
        for site in graph.collectives():
            if site.in_loop:
                out.append((f"{site.computation}/{site.op.kind}",
                            f"HLO collective {site.op.kind!r} executes "
                            f"inside a while body "
                            f"(×{site.multiplier:.0f} trip multiplier) — "
                            f"it re-issues every iteration and cannot "
                            f"overlap the pipeline"))
        return out
    for site in graph.sites():
        if site.primitive in COLLECTIVE_PRIMITIVES and site.loop_depth > 0:
            out.append((site.describe(),
                        f"collective {site.primitive!r} traced inside a "
                        f"loop body (depth {site.loop_depth}, "
                        f"trip×{site.trip}) — a statically-unrolled "
                        f"pipeline must keep its exchanges out of "
                        f"scan/while bodies"))
    return out


def _payload_sites(graph: JaxprGraph, model_size: int, chunk_rows: int,
                   d_model: int) -> List[EqnSite]:
    """The all_to_all sites that move an (…, chunk_rows, d_model) token
    window across ``model_size`` ranks — hierarchical stages reshape the
    leading rank axis (M,) → (inner, outer), so match on the trailing
    window shape plus the leading-axis product."""
    out = []
    for site in graph.find("all_to_all"):
        shapes = site.out_shapes
        if not shapes:
            continue
        s = shapes[0]
        if (len(s) >= 3 and s[-1] == d_model and s[-2] == chunk_rows
                and int(np.prod(s[:-2])) == model_size):
            out.append(site)
    return out


@register("overlap-chunk-count", "error", ("jaxpr",))
def _overlap_chunk_count(graph: JaxprGraph) -> List:
    """The grouped dispatch path with ``overlap_chunks = P`` must emit
    exactly ``moe.expected_grouped_a2a_eqns(cfg, model_size)`` separate
    ``all_to_all`` equations — P × (1 counts + stages dispatch + stages
    combine) — and the payload exchanges must move (M, B/P, d) windows,
    not the full bound.  Fewer equations means the pipeline collapsed
    (scan-folded or short-circuited); full-bound payloads mean the
    windows never actually split.  Applies to forward graphs traced with
    ``cfg``/``model_size``/``tokens_per_shard``/``d_model`` context.
    """
    from repro.core import capacity
    from repro.core import moe as moe_lib

    from repro.core import tuning

    ctx = graph.context
    cfg = ctx.get("cfg")
    model_size = int(ctx.get("model_size", 1))
    if (cfg is None or cfg.dispatch != "grouped" or model_size <= 1
            or ctx.get("direction", "fwd") != "fwd"
            or tuning.has_auto_knobs(cfg)):
        # "auto"-knob cells are owned by tuned-plan-consistency, which
        # resolves the sentinels the same way the trace did
        return []
    expected = moe_lib.expected_grouped_a2a_eqns(cfg, model_size)
    got = graph.count("all_to_all")
    out = []
    if got != expected:
        out.append(("all_to_all",
                    f"grouped dispatch with overlap_chunks="
                    f"{cfg.overlap_chunks}, a2a={cfg.a2a!r} must emit "
                    f"{expected} all_to_all equations, traced {got} — "
                    f"the overlap pipeline folded or short-circuited"))
    T = ctx.get("tokens_per_shard")
    d = ctx.get("d_model")
    if T is None or d is None:
        return out
    B = (capacity.grouped_segment_bound(cfg, int(T), model_size))
    P = cfg.overlap_chunks
    if B % P:
        return out            # bound validation owns this failure mode
    stages = moe_lib.grouped_a2a_stages(cfg, model_size)
    payload = _payload_sites(graph, model_size, B // P, int(d))
    want_payload = 2 * stages * P
    if len(payload) != want_payload:
        out.append(("all_to_all",
                    f"expected {want_payload} payload all_to_all "
                    f"equations moving ({model_size}, {B // P}, {d}) "
                    f"windows (bound B={B}, P={P}), found "
                    f"{len(payload)} — the microchunk windows did not "
                    f"split the bound"))
    return out


@register("tuned-plan-consistency", "error", ("jaxpr",))
def _tuned_plan_consistency(graph: JaxprGraph) -> List:
    """A graph traced under an ``"auto"``-knob config must match the
    knobs ``core/tuning.py`` resolves for that cell: exactly
    ``moe.expected_grouped_a2a_eqns(resolved, M)`` ``all_to_all``
    equations, whose payload exchanges move the resolved plan's
    ``(M, B/P, d)`` windows.  A mismatch means the trace and the tuner
    disagreed — a non-deterministic resolver, a code path reading the
    sentinel directly, or a stale plan cache — i.e. ``"auto"`` silently
    changed a traced graph shape.  Applies to forward grouped-EP graphs
    traced with ``cfg``/``model_size``/``tokens_per_shard``/``d_model``
    context where ``cfg`` carries a sentinel (PR 9 convention: concrete
    configs stay owned by ``overlap-chunk-count``).
    """
    from repro.core import capacity
    from repro.core import moe as moe_lib
    from repro.core import tuning

    ctx = graph.context
    cfg = ctx.get("cfg")
    model_size = int(ctx.get("model_size", 1))
    T = ctx.get("tokens_per_shard")
    d = ctx.get("d_model")
    if (cfg is None or not tuning.has_auto_knobs(cfg)
            or cfg.dispatch != "grouped" or model_size <= 1
            or ctx.get("direction", "fwd") != "fwd"
            or T is None or d is None):
        return []
    rcfg = tuning.resolve_moe_config(
        cfg, model_size=model_size, tokens_per_shard=int(T),
        d_model=int(d), dtype=ctx.get("dtype"))
    expected = moe_lib.expected_grouped_a2a_eqns(rcfg, model_size)
    got = graph.count("all_to_all")
    out = []
    if got != expected:
        out.append(("all_to_all",
                    f"resolved TunedPlan (a2a={rcfg.a2a!r}, a2a_inner="
                    f"{rcfg.a2a_inner}, overlap_chunks="
                    f"{rcfg.overlap_chunks}) expects {expected} "
                    f"all_to_all equations, traced {got} — the graph "
                    f"does not match what the tuner resolved for this "
                    f"cell"))
    B = capacity.grouped_segment_bound(rcfg, int(T), model_size)
    P = rcfg.overlap_chunks
    if B % P:
        return out
    stages = moe_lib.grouped_a2a_stages(rcfg, model_size)
    payload = _payload_sites(graph, model_size, B // P, int(d))
    want_payload = 2 * stages * P
    if len(payload) != want_payload:
        out.append(("all_to_all",
                    f"resolved TunedPlan expects {want_payload} payload "
                    f"all_to_all equations moving ({model_size}, "
                    f"{B // P}, {d}) windows (bound B={B}, P={P}), "
                    f"found {len(payload)} — the traced windows differ "
                    f"from the resolved plan"))
    return out


@register("payload-dtype", "error", ("jaxpr",))
def _payload_dtype_rule(graph: JaxprGraph) -> List:
    """The grouped exchange's payload AllToAll element type must match
    the RESOLVED config: the quantized wire dtype (int8 / fp8) when
    ``payload_dtype`` is set, the compute dtype when it is ``None`` — a
    payload-shaped exchange at the wrong element type means the
    quantize/dequantize pair was dropped (full-width wire, no β saving)
    or never undone (silent low-precision compute).  When quantized, no
    dot-like equation may consume the wire dtype directly: dequant
    happens between the exchange and the grouped matmuls, which keep
    accumulating in f32.  Applies to forward grouped-EP graphs traced
    with ``cfg``/``model_size``/``tokens_per_shard``/``d_model``/
    ``dtype`` context.
    """
    import jax.numpy as jnp

    from repro.core import alltoall, capacity, tuning

    ctx = graph.context
    cfg = ctx.get("cfg")
    model_size = int(ctx.get("model_size", 1))
    T = ctx.get("tokens_per_shard")
    d = ctx.get("d_model")
    if (cfg is None or cfg.dispatch != "grouped" or model_size <= 1
            or ctx.get("direction", "fwd") != "fwd"
            or T is None or d is None):
        return []
    rcfg = cfg
    if tuning.has_auto_knobs(cfg):
        if ctx.get("dtype") is None:
            return []                 # cannot resolve without the dtype
        rcfg = tuning.resolve_moe_config(
            cfg, model_size=model_size, tokens_per_shard=int(T),
            d_model=int(d), dtype=ctx.get("dtype"))
    if rcfg.payload_dtype is not None:
        wire = jnp.dtype(alltoall._payload_jnp_dtype(rcfg.payload_dtype))
    elif ctx.get("dtype") is not None:
        wire = jnp.dtype(ctx["dtype"])
    else:
        return []                     # nothing concrete to assert against
    B = capacity.grouped_segment_bound(rcfg, int(T), model_size)
    P = rcfg.overlap_chunks
    if B % P:
        return []                     # bound validation owns this cell
    out = []
    for site in _payload_sites(graph, model_size, B // P, int(d)):
        got = jnp.dtype(site.out_avals[0].dtype)
        if got != wire:
            out.append((site.describe(),
                        f"payload all_to_all emitted {got.name}, but the "
                        f"resolved payload_dtype="
                        f"{rcfg.payload_dtype!r} requires {wire.name} on "
                        f"the wire — the quantize/dequantize pair is "
                        f"missing or misplaced"))
    if rcfg.payload_dtype is not None:
        for site in graph.sites():
            if site.primitive not in DOT_PRIMITIVES:
                continue
            bad = [dt for dt in site.in_dtypes if jnp.dtype(dt) == wire]
            if bad:
                out.append((site.describe(),
                            f"dot-like equation consumes the "
                            f"{wire.name} wire dtype directly — the "
                            f"payload must be dequantized between the "
                            f"exchange and the grouped matmul (f32 "
                            f"accumulation)"))
    return out


@register("no-recompute-backward", "error", ("jaxpr",))
def _no_recompute_backward(graph: JaxprGraph) -> List:
    """A ``ragged_dot`` equation in a grouped-path GRADIENT graph.  The
    custom_vjp backward (PR 3) computes dlhs/drhs straight off the
    residuals with the Pallas kernels; ``ragged_dot`` appearing in a
    grad graph means ``jax.vjp(ragged_dot)`` re-ran the whole forward
    (2× the FLOPs, plus the f32-cotangent leak its transpose causes).
    Applies when the graph was traced with ``expect_no_ragged`` set, or
    with ``direction="grad"`` under a Pallas-kernel grouped config.
    """
    ctx = graph.context
    cfg = ctx.get("cfg")
    applies = bool(ctx.get("expect_no_ragged")) or (
        ctx.get("direction") == "grad" and cfg is not None
        and cfg.dispatch == "grouped" and cfg.use_pallas_gate)
    if not applies:
        return []
    return [(site.describe(),
             "ragged_dot in a backward graph — the grouped VJP must run "
             "the Pallas dlhs/drhs kernels off the residuals, not "
             "re-derive the forward through jax.vjp(ragged_dot)")
            for site in graph.find("ragged_dot")]


@register("dtype-leak", "error", ("jaxpr",))
def _dtype_leak(graph: JaxprGraph) -> List:
    """Mixed float operand dtypes on a dot-like equation.  ``lax``
    accepts an f32 operand against a bf16 one without complaint (that is
    how PR 4's f32 cotangents slipped into bf16 training graphs via
    ``ragged_dot``'s transpose); the result silently computes and stores
    in f32 — 2× the bytes on exactly the tensors the bf16 config was
    meant to shrink.  Accumulating in f32 is fine (and intended): this
    rule only fires when the *inputs* disagree, i.e. a cast is missing.
    """
    import jax.numpy as jnp

    out = []
    for site in graph.sites():
        if site.primitive not in DOT_PRIMITIVES:
            continue
        float_dts = {str(dt) for dt in site.in_dtypes
                     if jnp.issubdtype(dt, jnp.floating)}
        if len(float_dts) > 1:
            out.append((site.describe(),
                        f"{site.primitive} mixes float operand dtypes "
                        f"{sorted(float_dts)} — insert an explicit cast "
                        f"(f32 accumulation belongs in "
                        f"preferred_element_type / an output cast, not "
                        f"in a widened operand)"))
    return out


# ---------------------------------------------------------------------------
# shipped rules — probe side (runtime evidence, no graph)
# ---------------------------------------------------------------------------

@register("donation-alias", "error", ("probe",))
def _donation_alias(graph: ProbeGraph) -> List:
    """Two leaves of a donated pytree share one buffer (see
    ``training.train_step.donation_alias_pairs``, the single source of
    the aliasing check).  Context: ``donated`` = the pytree the driver
    donates (e.g. a ``TrainState``)."""
    from repro.training.train_step import donation_alias_pairs

    donated = graph.context.get("donated")
    if donated is None:
        return []
    return [(f"{a} ~ {b}",
             f"donated leaves {a} and {b} alias the same buffer — XLA "
             f"donation rejects the alias (or silently un-donates, "
             f"doubling state HBM); build distinct buffers")
            for a, b in donation_alias_pairs(donated)]


@register("retrace-budget", "error", ("probe",))
def _retrace_budget(graph: ProbeGraph) -> List:
    """A serving step-builder cache key traced more than ``budget``
    times (default 1).  Context: ``trace_counts`` (the
    ``serving.engine.trace_counts`` Counter, or any mapping key→count)
    and optional ``budget``.  More than one trace per key is the seed's
    re-jit-per-call bug resurfacing through an unhashable cache key."""
    from repro.serving.engine import trace_budget_report

    counts = graph.context.get("trace_counts")
    if counts is None:
        return []
    budget = int(graph.context.get("budget", 1))
    return [(str(key),
             f"step-builder key traced {n}x (budget {budget}) — "
             f"compiled-step cache miss on a repeated shape; check the "
             f"cache key covers every knob that changed")
            for key, n in trace_budget_report(budget, counts).items()]


@register("config-invalid", "error", ("probe",))
def _config_invalid(graph: ProbeGraph) -> List:
    """A config × mesh combination the repo's own validators reject
    (``moe.validate_dispatch_config``, ``engine.validate_decode_config``).
    The lint CLI converts the ``ValueError`` into this finding so a bad
    overlap bound passed via ``--config`` yields a report entry and a
    nonzero exit, not a traceback.  Context: ``config_error`` = the
    validator's message, ``label`` = the cell name."""
    err = graph.context.get("config_error")
    if not err:
        return []
    return [(str(graph.context.get("label", "<config>")), str(err))]
