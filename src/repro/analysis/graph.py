"""Structured graph representations for the lint rules.

The hazards this repo keeps hitting are *graph-shape* bugs — a collective
folded into a scan body, a recompute hiding in a backward sub-jaxpr, an
f32 operand sneaking into a bf16 dot — and substring-matching
``str(jax.make_jaxpr(...))`` cannot see structure: it miscounts when
primitive names nest (``all_to_all`` inside a transposed sub-jaxpr), and
it cannot tell a forward ``ragged_dot`` from one re-run by a VJP.

``JaxprGraph`` walks a (closed) jaxpr as a tree of equations, recursing
into every sub-jaxpr carried in ``eqn.params`` — ``scan``/``while``
bodies, ``cond`` branches, ``pjit``/``shard_map``/``custom_vjp``
call jaxprs, remat — and tags each equation site with

* ``path``       the enclosing primitive names, outermost first
                 (``("shard_map", "pjit", "scan")``),
* ``loop_depth`` how many *loop bodies* (``scan``/``while``) enclose it
                 (``cond`` branches and ``pjit`` calls do not count),
* ``trip``       the product of statically-known enclosing trip counts
                 (``scan``'s ``length``; 1 where unknown).

Rules consume sites through :meth:`JaxprGraph.sites` /
:meth:`JaxprGraph.find` / :meth:`JaxprGraph.count` and never look at the
string form.  ``ProbeGraph`` is the non-graph variant for rules over
runtime evidence (donated pytrees, ``engine.trace_counts``).
"""
from __future__ import annotations

from collections import Counter
from typing import Any, Dict, Iterator, List, NamedTuple, Optional, Tuple

import jax

try:  # public home since jax 0.4.35
    from jax.extend.core import ClosedJaxpr, Jaxpr, JaxprEqn
except ImportError:  # pragma: no cover - older jax
    from jax.core import ClosedJaxpr, Jaxpr, JaxprEqn  # type: ignore

# params whose sub-jaxpr is a LOOP BODY: entering it means the enclosed
# eqns execute once per iteration (scan also carries a static `length`).
_LOOP_PARAMS = {
    "scan": ("jaxpr",),
    "while": ("body_jaxpr", "cond_jaxpr"),
}


class EqnSite(NamedTuple):
    """One equation plus its structural context."""
    eqn: JaxprEqn
    path: Tuple[str, ...]        # enclosing primitive names, outermost first
    loop_depth: int              # enclosing scan/while bodies
    trip: int                    # product of known enclosing trip counts

    @property
    def primitive(self) -> str:
        return self.eqn.primitive.name

    @property
    def in_avals(self) -> Tuple[Any, ...]:
        return tuple(v.aval for v in self.eqn.invars if hasattr(v, "aval"))

    @property
    def out_avals(self) -> Tuple[Any, ...]:
        return tuple(v.aval for v in self.eqn.outvars if hasattr(v, "aval"))

    @property
    def out_shapes(self) -> Tuple[Tuple[int, ...], ...]:
        return tuple(tuple(a.shape) for a in self.out_avals
                     if hasattr(a, "shape"))

    @property
    def in_dtypes(self) -> Tuple[Any, ...]:
        return tuple(a.dtype for a in self.in_avals if hasattr(a, "dtype"))

    def describe(self) -> str:
        """Human-readable location: ``shard_map/scan/all_to_all``."""
        return "/".join(self.path + (self.primitive,))


def _sub_jaxprs(eqn: JaxprEqn) -> Iterator[Tuple[Jaxpr, bool, int]]:
    """Yield ``(jaxpr, is_loop_body, trip)`` for every sub-jaxpr carried
    in the equation's params (tuples/lists of jaxprs included — ``cond``
    branches)."""
    loop_keys = _LOOP_PARAMS.get(eqn.primitive.name, ())
    trip = int(eqn.params.get("length", 1) or 1) \
        if eqn.primitive.name == "scan" else 1
    for key, val in eqn.params.items():
        vals = val if isinstance(val, (tuple, list)) else (val,)
        for v in vals:
            if isinstance(v, ClosedJaxpr):
                v = v.jaxpr
            if isinstance(v, Jaxpr):
                yield v, key in loop_keys, (trip if key in loop_keys else 1)


def _walk(jaxpr: Jaxpr, path: Tuple[str, ...], loop_depth: int,
          trip: int) -> Iterator[EqnSite]:
    for eqn in jaxpr.eqns:
        yield EqnSite(eqn, path, loop_depth, trip)
        sub_path = path + (eqn.primitive.name,)
        for sub, is_loop, sub_trip in _sub_jaxprs(eqn):
            yield from _walk(sub, sub_path,
                             loop_depth + (1 if is_loop else 0),
                             trip * sub_trip)


class JaxprGraph:
    """A traced program plus the lint context it was traced under.

    ``context`` keys the shipped rules understand (all optional — a rule
    that misses its context simply does not apply):

      cfg                the ``MoEConfig`` the graph was traced with
      model_size         expert-parallel degree (mesh ``model`` axis)
      tokens_per_shard   static per-shard token count fed to the layer
      d_model            model width (payload-shape checks)
      direction          "fwd" | "grad"
      label              location prefix for findings (e.g. config name)
      expect_no_ragged   force the no-recompute-backward rule on
    """
    kind = "jaxpr"

    def __init__(self, closed: ClosedJaxpr,
                 context: Optional[Dict[str, Any]] = None):
        if not isinstance(closed, (ClosedJaxpr, Jaxpr)):
            raise TypeError(
                f"JaxprGraph wants a (Closed)Jaxpr — trace first with "
                f"jax.make_jaxpr or use analysis.trace_graph; got "
                f"{type(closed).__name__}")
        self.closed = closed
        self.jaxpr = closed.jaxpr if isinstance(closed, ClosedJaxpr) else closed
        self.context: Dict[str, Any] = dict(context or {})
        self._sites: Optional[List[EqnSite]] = None

    def sites(self) -> List[EqnSite]:
        if self._sites is None:
            self._sites = list(_walk(self.jaxpr, (), 0, 1))
        return self._sites

    def find(self, primitive: str) -> List[EqnSite]:
        return [s for s in self.sites() if s.primitive == primitive]

    def count(self, primitive: str) -> int:
        return len(self.find(primitive))

    def primitives(self) -> Counter:
        return Counter(s.primitive for s in self.sites())

    @property
    def label(self) -> str:
        return str(self.context.get("label", "<jaxpr>"))


class ProbeGraph:
    """Runtime-evidence 'graph' for the probe rules (donation aliasing,
    serving retrace budget).  Carries only ``context``."""
    kind = "probe"

    def __init__(self, context: Optional[Dict[str, Any]] = None):
        self.context: Dict[str, Any] = dict(context or {})

    @property
    def label(self) -> str:
        return str(self.context.get("label", "<probe>"))


def trace_graph(fn, *args, context: Optional[Dict[str, Any]] = None,
                **make_jaxpr_kwargs) -> JaxprGraph:
    """``jax.make_jaxpr`` + wrap: the one-liner the tests and the lint
    CLI use instead of ``str(jax.make_jaxpr(...))`` grepping."""
    closed = jax.make_jaxpr(fn, **make_jaxpr_kwargs)(*args)
    return JaxprGraph(closed, context=context)
