"""HLO-side graph for the lint rules.

Jaxpr lint catches what we *traced*; this catches what the compiler
*emitted* — the two can disagree (XLA may fold, fuse, or re-schedule
collectives after the fact).  ``HloGraph`` reuses the module parser and
the call-graph/loop-multiplier walk from ``launch/hlo_analysis.py`` (one
parser for the roofline AND the linter) and exposes compiled ops with
the same structural context the jaxpr walker gives: which computation
each op lives in, its while-trip multiplier, and whether it executes
inside a loop body.
"""
from __future__ import annotations

from typing import Any, Dict, List, NamedTuple, Optional

from repro.launch import hlo_analysis as H


class HloOpSite(NamedTuple):
    op: H.Op                 # parsed op (kind, result bytes/dims, raw line)
    computation: str         # enclosing computation name
    multiplier: float        # while-trip multiplier (1.0 at top level)
    in_loop: bool            # reached through a while body/cond edge

    def describe(self) -> str:
        return f"{self.computation}/{self.op.kind}"


class HloGraph:
    """Parsed compiled-module text + lint context (see ``JaxprGraph``
    for the context keys).  ``graph.kind == "hlo"`` selects the HLO
    variants of the registered rules."""
    kind = "hlo"

    def __init__(self, text: str, context: Optional[Dict[str, Any]] = None,
                 entry: Optional[str] = None):
        self.text = text
        self.context: Dict[str, Any] = dict(context or {})
        self.comps, self.shapes = H.parse_module(text)
        if not self.comps:
            raise ValueError(
                "HloGraph: no computations parsed — pass compiled module "
                "text (jit(f).lower(...).compile().as_text())")
        self.entry = entry or H.find_entry(text, self.comps)
        self.mult, self.fused, self.in_loop = H.call_graph(self.comps,
                                                           self.entry)

    def sites(self) -> List[HloOpSite]:
        out = []
        for comp, ops in self.comps.items():
            m = self.mult.get(comp)
            if m is None:            # unreachable / dead computation
                continue
            looped = self.in_loop.get(comp, False)
            for op in ops:
                out.append(HloOpSite(op, comp, m, looped))
        return out

    def find(self, kind: str) -> List[HloOpSite]:
        """Ops of one HLO kind; ``-start`` async halves fold into their
        base kind (``all-to-all-start`` → ``all-to-all``)."""
        return [s for s in self.sites()
                if s.op.kind.replace("-start", "") == kind]

    def count(self, kind: str) -> int:
        return len(self.find(kind))

    def collectives(self) -> List[HloOpSite]:
        return [s for s in self.sites()
                if s.op.kind.replace("-start", "") in H.COLLECTIVE_KINDS]

    @property
    def label(self) -> str:
        return str(self.context.get("label", "<hlo>"))
