"""Graph-invariant linter: structured static analysis of traced jaxprs
and compiled HLO for the MoE stack.

The hazards that sink this system are graph-SHAPE bugs, not value bugs:
a pipeline's collectives folding into one scan-body equation (PR 5), f32
cotangents leaking into bf16 dots through ``ragged_dot``'s transpose
(PR 4), a serving path re-tracing per call (PR 7).  Outputs stay
numerically right while the emitted program quietly loses the property
the PR shipped.  This package checks the *program*:

* ``graph.JaxprGraph`` — a structured equation walker (recurses into
  scan/while/cond/pjit/shard_map/custom_vjp sub-jaxprs with loop-context
  tracking; no string matching),
* ``hlo.HloGraph`` — the compiled-module view, reusing
  ``launch/hlo_analysis.py``'s parser and loop-multiplier call graph,
* ``rules`` — a registry of ``Rule(name, level, check(Graph) ->
  [Finding])`` encoding every graph invariant the repo has shipped,
* ``lint`` — the config-matrix CLI:
  ``python -m repro.analysis.lint [--config NAME] [--json out.json]``
  traces sort/grouped × {1-rank, EP4, TP, EP×TP} × flat/hier ×
  overlap P ∈ {1,2,4}, writes a ``LINT_moe.json`` report, and exits
  nonzero on error-level findings.

Library entry points::

    from repro import analysis

    g = analysis.trace_graph(fn, *args, context={"cfg": cfg,
                                                 "model_size": 4, ...})
    findings = analysis.lint_jaxpr(g)            # all jaxpr rules
    findings = analysis.run_rule("dtype-leak", g)  # one rule
    findings = analysis.lint_hlo(compiled_text, context={...})
    findings = analysis.lint_probe(donated=train_state)

Adding a rule — the "new graph invariant ⇒ new rule + known-bad test"
convention (ROADMAP process note)::

    # 1. encode the invariant over the structured walker
    from repro.analysis.rules import register

    @register("fp8-payload", "error", ("jaxpr",))
    def _fp8_payload(graph):
        '''Quantized exchange payloads must cross the mesh in f8, not
        re-widened bf16.'''
        return [(site.describe(), "exchange payload widened before a2a")
                for site in graph.find("all_to_all")
                if any(str(d) == "bfloat16" for d in site.in_dtypes)]

    # 2. ship a KNOWN-BAD graph that makes it fire
    #    (tests/test_analysis.py: trace a deliberately-widened exchange,
    #    assert the finding), plus keep the clean matrix green.

Findings carry ``(rule, level, location, message, config)``; ``location``
is the structural path (``shard_map/scan/all_to_all``), so a finding
names WHERE in the program the invariant broke, not a substring offset.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, List, Optional

from repro.analysis.graph import (EqnSite, JaxprGraph, ProbeGraph,
                                  trace_graph)
from repro.analysis.hlo import HloGraph, HloOpSite
from repro.analysis.rules import (COLLECTIVE_PRIMITIVES, DOT_PRIMITIVES,
                                  LEVELS, REGISTRY, Finding, Rule,
                                  lint_graph, register, rules_for, run_rule)

__all__ = [
    "COLLECTIVE_PRIMITIVES", "DOT_PRIMITIVES", "EqnSite", "Finding",
    "HloGraph", "HloOpSite", "JaxprGraph", "LEVELS", "ProbeGraph",
    "REGISTRY", "Rule", "lint_graph", "lint_hlo", "lint_jaxpr",
    "lint_probe", "register", "rules_for", "run_rule", "trace_graph",
]


def lint_jaxpr(graph_or_jaxpr, *, context: Optional[Dict[str, Any]] = None,
               rules: Optional[Iterable[str]] = None) -> List[Finding]:
    """Run the registered jaxpr rules.  Accepts a ``JaxprGraph`` or a
    raw (closed) jaxpr (wrapped with ``context``)."""
    g = (graph_or_jaxpr if isinstance(graph_or_jaxpr, JaxprGraph)
         else JaxprGraph(graph_or_jaxpr, context=context))
    return lint_graph(g, rules)


def lint_hlo(text_or_graph, *, context: Optional[Dict[str, Any]] = None,
             rules: Optional[Iterable[str]] = None) -> List[Finding]:
    """Run the registered HLO rules over compiled-module text (or an
    already-parsed ``HloGraph``)."""
    g = (text_or_graph if isinstance(text_or_graph, HloGraph)
         else HloGraph(text_or_graph, context=context))
    return lint_graph(g, rules)


def lint_probe(rules: Optional[Iterable[str]] = None,
               **context) -> List[Finding]:
    """Run the probe rules over runtime evidence, e.g.
    ``lint_probe(donated=state)`` or
    ``lint_probe(trace_counts=engine.trace_counts)``."""
    return lint_graph(ProbeGraph(context), rules)
