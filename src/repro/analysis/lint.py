"""Config-matrix lint CLI.

  PYTHONPATH=src python -m repro.analysis.lint                 # full matrix
  PYTHONPATH=src python -m repro.analysis.lint --config NAME   # one cell
  PYTHONPATH=src python -m repro.analysis.lint --json out.json
  PYTHONPATH=src python -m repro.analysis.lint --list

Traces the standard dispatch config matrix — sort/grouped × {1-rank,
EP4, TP2, EP2×TP2} × flat/hier × overlap P ∈ {1, 2, 4}, plus one fully
auto-tuned cell per mesh (``grouped/<mesh>/auto/Pauto``: every grouped
knob the ``core/tuning.py`` sentinel, checked by the
``tuned-plan-consistency`` rule), plus quantized-wire cells carrying a
fifth ``/<payload_dtype>`` path component (``payload-dtype`` rule) —
through ``sharded_moe_apply`` on the 8-fake-CPU-device backend, runs
every registered jaxpr rule over the forward graphs and (grouped cells,
the Pallas kernel path) the gradient graphs, lints one representative
cell's COMPILED HLO, and runs the probe rules (donation aliasing on a
real ``init_train_state``, serving retrace budget on repeated
``generate()`` calls).  Cell names look like ``grouped/ep4/hier/P2``,
``grouped/ep4/flat/P2/int8`` (quantized exchange wire) and
``decode/ep4/grouped/P1`` (serving step-BUILD validation cells).

A config×mesh combination the validators reject (``--config`` with a
bad overlap bound, an indivisible hierarchical inner) produces a
``config-invalid`` FINDING, not a traceback — the lint report is the
interface, exit code 1 means error-level findings exist.

Report: ``LINT_moe.json`` at the repo root (or ``--json PATH``) with
``{schema, rules, matrix, findings[{rule, level, location, message,
config}], summary}`` — diffable by subprocess tests the same way
``tests/test_bench_gate.py`` diffs ``BENCH_moe.json``.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import pathlib  # noqa: E402
import sys  # noqa: E402
from typing import Dict, List, Optional, Tuple  # noqa: E402

JSON_PATH = pathlib.Path(__file__).resolve().parents[3] / "LINT_moe.json"
SCHEMA = "lint_moe/v1"

# one token block shaped (4, 16, D): 64 tokens, sharded over every mesh
# axis by sharded_moe_apply — per-shard counts below derive from this
TOKENS = (4, 16)
D_MODEL = 32
D_FF = 64
E = 8

# mesh key → (shape, axis names, expert-TP axis)
MESHES: Dict[str, Tuple[Tuple[int, ...], Tuple[str, ...], Optional[str]]] = {
    "r1":     ((1, 1), ("data", "model"), None),
    "ep4":    ((4,),   ("model",),        None),
    "tp2":    ((2, 1), ("data", "model"), "data"),
    "ep2tp2": ((2, 2), ("data", "model"), "data"),
}
A2A = {"flat": ("flat", 1), "hier": ("hierarchical", 2),
       "auto": ("auto", 1)}

# the representative cell whose COMPILED module gets the HLO-side pass
HLO_CELL = "grouped/ep4/flat/P2"


def _mesh(key: str):
    from repro.launch.mesh import make_smoke_mesh
    shape, axes, tp = MESHES[key]
    return make_smoke_mesh(shape, axes), tp


def _model_size(key: str) -> int:
    shape, axes, _ = MESHES[key]
    return dict(zip(axes, shape)).get("model", 1)


def _tokens_per_shard(key: str) -> int:
    shape, _, _ = MESHES[key]
    n_dev = 1
    for s in shape:
        n_dev *= s
    total = TOKENS[0] * TOKENS[1]
    return (total + (-total) % n_dev) // n_dev


def matrix_cells() -> List[str]:
    """The standard config matrix, as cell names."""
    cells = []
    for mesh_key in MESHES:
        a2as = ("flat", "hier") if _model_size(mesh_key) > 1 else ("flat",)
        for a2a in a2as:
            cells.append(f"sort/{mesh_key}/{a2a}/P1")
            for P in (1, 2, 4):
                cells.append(f"grouped/{mesh_key}/{a2a}/P{P}")
        # fully auto-tuned cell: every grouped knob a sentinel, resolved
        # by core/tuning.py — linted by tuned-plan-consistency
        cells.append(f"grouped/{mesh_key}/auto/Pauto")
    # quantized exchange-wire cells (payload-dtype rule): int8 on the
    # flat and overlapped EP paths + the EP×TP mesh, one fp8 witness
    cells += ["grouped/ep4/flat/P1/int8", "grouped/ep4/flat/P2/int8",
              "grouped/ep4/hier/P1/float8_e4m3fn",
              "grouped/ep2tp2/flat/P2/int8"]
    # serving step-BUILD validation cells (engine.validate_decode_config)
    cells += ["decode/r1/grouped/P1", "decode/ep4/grouped/P1",
              "decode/ep4/grouped/Pauto", "decode/ep4/grouped/P1/int8"]
    return cells


def parse_cell(name: str) -> Dict:
    """``dispatch/mesh/a2a/P<n>[/payload_dtype]`` or
    ``decode/mesh/dispatch/P<n>[/payload_dtype]`` → spec dict.  Unknown
    vocabulary raises ValueError naming the options; a VALID name with
    an invalid config combination (P that does not divide the bound)
    parses fine and surfaces as a config-invalid finding from the
    validators instead."""
    from repro.core.config import DISPATCH_MODES, PAYLOAD_DTYPES

    parts = name.split("/")
    err = (f"bad lint cell {name!r}: expected "
           f"dispatch/mesh/a2a/P<n>[/payload_dtype] (dispatch in "
           f"{DISPATCH_MODES}, mesh in {tuple(MESHES)}, a2a in "
           f"{tuple(A2A)}, payload_dtype in {PAYLOAD_DTYPES}) or "
           f"decode/mesh/dispatch/P<n>[/payload_dtype]")
    payload = None
    if len(parts) == 5:
        payload = parts[4]
        if payload not in PAYLOAD_DTYPES:
            raise ValueError(err)
        parts = parts[:4]
    if len(parts) != 4:
        raise ValueError(err)
    if parts[0] == "decode":
        _, mesh_key, dispatch, p = parts
        a2a = "flat"
    else:
        dispatch, mesh_key, a2a, p = parts
    if (dispatch not in DISPATCH_MODES or mesh_key not in MESHES
            or a2a not in A2A or not p.startswith("P")):
        raise ValueError(err)
    if p == "Pauto":
        P = "auto"
    else:
        try:
            P = int(p[1:])
        except ValueError:
            raise ValueError(err)
    return {"name": name, "decode": parts[0] == "decode",
            "dispatch": dispatch, "mesh": mesh_key, "a2a": a2a, "P": P,
            "payload": payload}


def _cell_cfg(spec: Dict, *, use_pallas: bool = False):
    from repro.core.config import MoEConfig
    a2a, inner = A2A[spec["a2a"]]
    kw = {}
    if spec["a2a"] == "auto":
        # the fully auto-tuned cell carries every sentinel the tuner owns
        kw.update(grouped_block_m="auto", grouped_ep_bound_factor="auto")
    return MoEConfig(num_experts=E, dispatch=spec["dispatch"], gate="topk",
                     top_k=2, capacity_factor=8.0, a2a=a2a, a2a_inner=inner,
                     overlap_chunks=spec["P"], use_pallas_gate=use_pallas,
                     payload_dtype=spec.get("payload"), **kw)


def lint_cell(name: str, rules=None) -> List:
    """Lint one matrix cell.  Traces the forward (and, grouped cells,
    the Pallas-path gradient) graph and runs the registered jaxpr rules;
    validator rejections become ``config-invalid`` findings."""
    import jax
    import jax.numpy as jnp

    from repro import analysis
    from repro.core import moe

    spec = parse_cell(name)
    if spec["decode"]:
        return _lint_decode_cell(spec)
    mesh, tp = _mesh(spec["mesh"])
    model_size = _model_size(spec["mesh"])
    T = _tokens_per_shard(spec["mesh"])
    cfg = _cell_cfg(spec)
    try:
        moe.validate_dispatch_config(cfg, model_size=model_size,
                                     tokens_per_shard=T, d_model=D_MODEL,
                                     dtype=jnp.bfloat16)
    except ValueError as e:
        return analysis.lint_probe(config_error=str(e), label=name)

    params = moe.init_moe_params(jax.random.PRNGKey(0), cfg, D_MODEL, D_FF,
                                 E, act="swiglu", dtype=jnp.bfloat16)
    x = jax.random.normal(jax.random.PRNGKey(1), (*TOKENS, D_MODEL),
                          jnp.bfloat16)
    ctx = {"cfg": cfg, "model_size": model_size, "tokens_per_shard": T,
           "d_model": D_MODEL, "dtype": jnp.bfloat16, "label": name,
           "direction": "fwd"}

    def fwd(p, v):
        return moe.sharded_moe_apply(mesh, cfg, p, v, num_experts=E,
                                     act="swiglu", expert_tp_axis=tp)

    findings = analysis.lint_jaxpr(
        analysis.trace_graph(fwd, params, x, context=ctx), rules=rules)

    if spec["dispatch"] == "grouped":
        # gradient graph through the production (Pallas) kernel path:
        # the no-recompute-backward invariant lives here
        gcfg = _cell_cfg(spec, use_pallas=True)

        def loss(p, v):
            y, aux, _ = moe.sharded_moe_apply(
                mesh, gcfg, p, v, num_experts=E, act="swiglu",
                expert_tp_axis=tp)
            return jnp.sum(y.astype(jnp.float32) ** 2) + aux

        gctx = dict(ctx, cfg=gcfg, direction="grad", label=name + ":grad")
        findings += analysis.lint_jaxpr(
            analysis.trace_graph(jax.grad(loss), params, x, context=gctx),
            rules=rules)
    return findings


def _lint_decode_cell(spec: Dict) -> List:
    """Serving step-BUILD validation: route the cell's dispatch/overlap
    through ``engine.validate_decode_config`` (which folds in
    ``moe.validate_dispatch_config`` at the decode batch's static token
    count) — rejections become findings, clean cells return none."""
    from repro import analysis, configs
    from repro.serving import engine

    mesh, _ = _mesh(spec["mesh"])
    base = configs.smoke_config("dbrx-132b")
    cfg = base.replace(moe=dataclasses.replace(
        base.moe, dispatch="grouped", overlap_chunks=spec["P"]))
    try:
        cfg = engine.serve_config(cfg, dispatch=spec["dispatch"],
                                  payload_dtype=spec.get("payload"))
        engine.validate_decode_config(cfg, mesh, batch=4, cache_len=32)
    except ValueError as e:
        return analysis.lint_probe(config_error=str(e), label=spec["name"])
    return []


def lint_hlo_cell(name: str = HLO_CELL, rules=None) -> List:
    """Compile one cell and lint the emitted module — the jaxpr pass
    checks what we traced, this checks what XLA actually scheduled."""
    import jax
    import jax.numpy as jnp

    from repro import analysis
    from repro.core import moe

    spec = parse_cell(name)
    mesh, tp = _mesh(spec["mesh"])
    cfg = _cell_cfg(spec)
    params = moe.init_moe_params(jax.random.PRNGKey(0), cfg, D_MODEL, D_FF,
                                 E, act="swiglu", dtype=jnp.bfloat16)
    x = jax.random.normal(jax.random.PRNGKey(1), (*TOKENS, D_MODEL),
                          jnp.bfloat16)
    compiled = jax.jit(lambda p, v: moe.sharded_moe_apply(
        mesh, cfg, p, v, num_experts=E, act="swiglu",
        expert_tp_axis=tp)).lower(params, x).compile()
    text = compiled.as_text()
    return analysis.lint_hlo(text, context={"label": name + ":hlo"},
                             rules=rules)


def lint_probes() -> List:
    """Runtime-evidence probes: donation aliasing on a real
    ``init_train_state`` tree, and the serving retrace budget across
    repeated ``generate()`` calls (the PR 7 no-re-jit contract)."""
    import jax

    from repro import analysis, configs
    from repro.core.config import TrainConfig
    from repro.serving import engine, generate
    from repro.training.train_step import init_train_state

    findings = []
    cfg = configs.smoke_config("dbrx-132b").replace(dtype="float32")
    state = init_train_state(jax.random.PRNGKey(0), cfg, TrainConfig())
    findings += analysis.lint_probe(donated=state, label="probe/donation")

    mesh, _ = _mesh("r1")
    params = state.params
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                cfg.vocab_size)
    engine.clear_step_cache()
    for _ in range(2):   # identical shapes: every key must trace once
        generate(params, cfg, prompt, steps=3, mesh=mesh,
                 dispatch="grouped")
    findings += analysis.lint_probe(trace_counts=dict(engine.trace_counts),
                                    label="probe/retrace")
    return findings


def write_report(path: pathlib.Path, cells: List[str], findings: List,
                 rules_run: List[str]) -> Dict:
    from repro.analysis.rules import REGISTRY
    summary = {"error": 0, "warn": 0, "info": 0}
    for f in findings:
        summary[f.level] = summary.get(f.level, 0) + 1
    report = {
        "schema": SCHEMA,
        "rules": {n: {"level": REGISTRY[n].level,
                      "doc": (REGISTRY[n].doc or "").strip()
                      .split("\n")[0].strip()}
                  for n in sorted(rules_run)},
        "matrix": cells,
        "findings": [f.as_dict() for f in findings],
        "summary": dict(summary, cells=len(cells)),
    }
    path.write_text(json.dumps(report, indent=2) + "\n")
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="graph-invariant lint over the MoE dispatch config "
                    "matrix; exit 1 on error-level findings")
    ap.add_argument("--config", default=None, metavar="NAME",
                    help="lint ONE cell (e.g. grouped/ep4/hier/P2 or "
                         "decode/ep4/grouped/P5); default: full matrix")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help=f"report path (default {JSON_PATH.name} at the "
                         f"repo root)")
    ap.add_argument("--rules", default=None,
                    help="comma list restricting which rules run")
    ap.add_argument("--no-hlo", action="store_true",
                    help="skip the compiled-HLO pass")
    ap.add_argument("--no-probes", action="store_true",
                    help="skip the runtime probes (donation, retrace)")
    ap.add_argument("--list", action="store_true",
                    help="print matrix cells and registered rules")
    args = ap.parse_args(argv)

    import repro.analysis as analysis  # registers the rules

    rules = args.rules.split(",") if args.rules else None
    if rules:
        try:
            analysis.rules_for("jaxpr", rules)
        except ValueError as e:
            ap.error(str(e))
    if args.list:
        for c in matrix_cells():
            print(c)
        for name, rule in sorted(analysis.REGISTRY.items()):
            print(f"rule {name} [{rule.level}] kinds={','.join(rule.kinds)}")
        return 0

    if args.config:
        try:
            cells = [parse_cell(args.config)["name"]]
        except ValueError as e:
            ap.error(str(e))
    else:
        cells = matrix_cells()

    findings = []
    for cell in cells:
        cell_findings = lint_cell(cell, rules=rules)
        findings += cell_findings
        status = ("clean" if not cell_findings
                  else f"{len(cell_findings)} finding(s)")
        print(f"# {cell}: {status}")
        for f in cell_findings:
            print(f"#   [{f.level}] {f.rule} @ {f.location}: {f.message}")
        sys.stdout.flush()

    if not args.config:
        if not args.no_hlo:
            hlo_findings = lint_hlo_cell(rules=rules)
            print(f"# {HLO_CELL}:hlo: "
                  f"{'clean' if not hlo_findings else len(hlo_findings)}")
            findings += hlo_findings
        if not args.no_probes:
            probe_findings = lint_probes()
            print(f"# probes: "
                  f"{'clean' if not probe_findings else len(probe_findings)}")
            findings += probe_findings

    rules_run = (rules if rules is not None else sorted(analysis.REGISTRY))
    report = write_report(pathlib.Path(args.json) if args.json else JSON_PATH,
                          cells, findings, rules_run)
    n_err = report["summary"]["error"]
    print(f"# lint: {len(cells)} cells, {len(findings)} finding(s), "
          f"{n_err} error(s) -> "
          f"{pathlib.Path(args.json) if args.json else JSON_PATH}")
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
