"""Fused softmax+top-k gating kernel (paper §3.2 "Gate Optimization", Fig. 3).

HetuMoE's CUDA kernel beats PyTorch ``topk`` ~25% by specializing for the
small k (1, 2) that MoE gates actually use.  The TPU adaptation
(DESIGN.md §2): instead of fighting kernel-launch overhead, we fuse the
row-softmax statistics (max, Σexp) and the iterative-max top-k into ONE
VMEM pass over the (tokens, experts) tile — replacing XLA's generic
O(E·logE) ``sort``-based top-k plus separate softmax HLOs with an
O(k·E) VPU loop that reads the logits once.

Tiling: grid over token tiles of ``block_s`` rows; the expert dimension
(≤ a few hundred in practice) stays resident in VMEM lanes.  All compute
f32 on the VPU; no MXU use.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _topk_gate_kernel(x_ref, vals_ref, idx_ref, max_ref, sumexp_ref, *, k: int):
    x = x_ref[...].astype(jnp.float32)                     # (TS, E)
    E = x.shape[-1]
    rowmax = jnp.max(x, axis=-1, keepdims=True)
    max_ref[...] = rowmax
    sumexp_ref[...] = jnp.sum(jnp.exp(x - rowmax), axis=-1, keepdims=True)
    # iterative max: k passes, mask out the winner each time.  Ties break
    # to the lowest index (same as argmax / the jnp oracle).
    iota = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    cur = x
    for j in range(k):
        m = jnp.max(cur, axis=-1, keepdims=True)
        am = jnp.min(jnp.where(cur == m, iota, E), axis=-1, keepdims=True)
        vals_ref[:, j:j + 1] = m
        idx_ref[:, j:j + 1] = am
        cur = jnp.where(iota == am, -jnp.inf, cur)


@functools.partial(jax.jit, static_argnames=("k", "block_s", "interpret"))
def fused_topk_gate(logits: jax.Array, k: int, *, block_s: int = 256,
                    interpret: bool = True):
    """One-pass softmax stats + top-k.

    Returns ``(vals (S,k) f32, idx (S,k) i32, rowmax (S,1), sumexp (S,1))``
    so the caller derives softmax weights ``exp(vals-rowmax)/sumexp`` and
    full router probs without re-reading the logits.
    """
    S, E = logits.shape
    bs = min(block_s, S)
    pad = (-S) % bs
    if pad:
        logits = jnp.pad(logits, ((0, pad), (0, 0)), constant_values=-jnp.inf)
    Sp = S + pad
    grid = (Sp // bs,)
    out_shapes = (
        jax.ShapeDtypeStruct((Sp, k), jnp.float32),
        jax.ShapeDtypeStruct((Sp, k), jnp.int32),
        jax.ShapeDtypeStruct((Sp, 1), jnp.float32),
        jax.ShapeDtypeStruct((Sp, 1), jnp.float32),
    )
    row_block = lambda cols: pl.BlockSpec((bs, cols), lambda i: (i, 0))
    vals, idx, rowmax, sumexp = pl.pallas_call(
        functools.partial(_topk_gate_kernel, k=k),
        grid=grid,
        in_specs=[row_block(E)],
        out_specs=(row_block(k), row_block(k), row_block(1), row_block(1)),
        out_shape=out_shapes,
        interpret=interpret,
    )(logits)
    return vals[:S], idx[:S], rowmax[:S], sumexp[:S]
