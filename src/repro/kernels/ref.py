"""Pure-jnp oracles for every Pallas kernel (allclose-asserted in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_topk_gate(logits: jax.Array, k: int):
    """Oracle for kernels.topk_gate.fused_topk_gate."""
    logits = logits.astype(jnp.float32)
    rowmax = jnp.max(logits, axis=-1, keepdims=True)
    sumexp = jnp.sum(jnp.exp(logits - rowmax), axis=-1, keepdims=True)
    vals, idx = jax.lax.top_k(logits, k)
    return vals, idx.astype(jnp.int32), rowmax, sumexp


def ref_gather_rows(src: jax.Array, idx: jax.Array):
    """Oracle for kernels.layout_transform.gather_rows."""
    safe = jnp.maximum(idx, 0)
    out = src[safe]
    return jnp.where((idx >= 0)[:, None], out, 0)
