"""Grouped (ragged) expert matmuls for the dropless dispatch mode.

The ``grouped`` dispatch packs tokens into an expert-sorted ``(M, d)``
buffer whose per-expert segment lengths are data-dependent; the expert
FFN then needs ``y[offs[e]:offs[e+1]] = x[offs[e]:offs[e+1]] @ w[e]`` —
a grouped matmul (MegaBlocks' dMoE primitive).  Two implementations:

``ragged``  ``jax.lax.ragged_dot`` — XLA's native ragged primitive, used
            as the jnp reference path.
``pallas``  Blocked kernel: grid ``(M/block_m, E)``; each row-block visits
            each expert, but a ``pl.when`` predicate skips (expert,
            block) pairs whose row ranges don't overlap — with sorted
            rows a block overlaps ~1-2 experts, so the MXU work is
            Σ_e ceil(n_e / block_m) tiles, not M/block_m · E.  The
            group-offset vector is scalar-prefetched into SMEM and rows
            outside the active expert's range are masked before the dot.

Rows past ``offsets[-1]`` (the virtual drop bucket's tail under token
padding) belong to no expert and come out zero — matching ragged_dot.

Expert tensor parallelism needs no kernel variant: the kernels are
shape-polymorphic in the weights' f dim, so the TP path simply passes
the local f-slice — ``w_up/w_gate (E, d, f/R)`` and ``w_out (E, f/R,
d)``.  The up/gate matmuls then emit f/R-wide activations (swiglu /
geglu are elementwise in f, so the slices compose locally), the out
matmul contracts the f/R slice into a PARTIAL (M, d) sum, and the
caller's psum_scatter over the TP axis completes the contraction.  The
Pallas backward inherits this for free — dlhs sums R partials through
the same psum (the psum_scatter transpose), drhs produces each rank's
own (d, f/R) / (f/R, d) weight-gradient slice locally.

The ``custom_vjp`` backward is kernelized too (MegaBlocks trains the
dMoE primitive in both directions) — no forward recompute, both
gradients straight off the residuals:

  dlhs  the SAME blocked grouped-matmul kernel with ``rhs`` transposed
        on its last two dims (the ``transpose_rhs`` flag — a tile-level
        transpose in-kernel, no HBM copy of the expert weights):
        ``dlhs[seg_e] = g[seg_e] @ rhs[e]ᵀ``.
  drhs  a segment-wise outer-product accumulation kernel: grid
        ``(E, M/block_m)``, the scalar-prefetched offsets predicate
        which row-blocks contribute to expert e's ``(K, N)`` gradient
        tile, masked rows zeroed, partial products accumulated in f32
        (``drhs[e] = lhs[seg_e]ᵀ @ g[seg_e]``).
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_M = 128


def _grouped_matmul_kernel(offs_ref, lhs_ref, rhs_ref, out_ref, *,
                           block_m: int, transpose_rhs: bool):
    i, e = pl.program_id(0), pl.program_id(1)

    @pl.when(e == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    row0 = i * block_m
    lo, hi = offs_ref[e], offs_ref[e + 1]

    @pl.when(jnp.logical_and(hi > row0, lo < row0 + block_m))
    def _tile():
        rows = row0 + jax.lax.broadcasted_iota(jnp.int32, (block_m, 1), 0)
        mask = (rows >= lo) & (rows < hi)
        x = jnp.where(mask, lhs_ref[...], 0)
        # transpose_rhs serves the dlhs backward: the (K, N) tile is
        # transposed in-register, so the caller never materializes an
        # (E, N, K) copy of the expert weights in HBM
        w = rhs_ref[0].T if transpose_rhs else rhs_ref[0]
        # out_ref is f32 regardless of input dtype: partial sums must not
        # round to bf16 (the sort path's einsum accumulates f32 too)
        out_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("interpret", "block_m", "transpose_rhs"))
def _grouped_matmul_impl(lhs: jax.Array, rhs: jax.Array, offsets: jax.Array,
                         *, interpret: bool = True,
                         block_m: int = DEFAULT_BLOCK_M,
                         transpose_rhs: bool = False) -> jax.Array:
    """y[seg_e] = lhs[seg_e] @ rhs[e] — or @ rhs[e].T with
    ``transpose_rhs`` (the dlhs backward; lhs is then (M, N) → (M, K))."""
    M, _ = lhs.shape
    E, K, N = rhs.shape
    n_out = K if transpose_rhs else N
    bm = min(block_m, M)
    pad = (-M) % bm
    if pad:
        lhs = jnp.concatenate(
            [lhs, jnp.zeros((pad, lhs.shape[1]), lhs.dtype)])
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=((M + pad) // bm, E),
        in_specs=[
            pl.BlockSpec((bm, lhs.shape[1]), lambda i, e, offs: (i, 0)),
            pl.BlockSpec((1, K, N), lambda i, e, offs: (e, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n_out), lambda i, e, offs: (i, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_grouped_matmul_kernel, block_m=bm,
                          transpose_rhs=transpose_rhs),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M + pad, n_out), jnp.float32),
        interpret=interpret,
    )(offsets.astype(jnp.int32), lhs, rhs)
    return (out[:M] if pad else out).astype(lhs.dtype)


def _grouped_drhs_kernel(offs_ref, lhs_ref, g_ref, out_ref, *,
                         block_m: int):
    e, i = pl.program_id(0), pl.program_id(1)

    @pl.when(i == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    row0 = i * block_m
    lo, hi = offs_ref[e], offs_ref[e + 1]

    @pl.when(jnp.logical_and(hi > row0, lo < row0 + block_m))
    def _tile():
        rows = row0 + jax.lax.broadcasted_iota(jnp.int32, (block_m, 1), 0)
        mask = (rows >= lo) & (rows < hi)
        # masking ONE operand suffices: rows outside [lo, hi) — including
        # the virtual drop bucket's tail — contribute a zero outer product
        x = jnp.where(mask, lhs_ref[...], 0)
        out_ref[0] += jnp.dot(x.T, g_ref[...],
                              preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret", "block_m"))
def _grouped_drhs_impl(lhs: jax.Array, g: jax.Array, offsets: jax.Array,
                       *, interpret: bool = True,
                       block_m: int = DEFAULT_BLOCK_M) -> jax.Array:
    """drhs (E, K, N) f32 with drhs[e] = lhs[seg_e].T @ g[seg_e].

    Grid (E, M/block_m): expert-major so each expert's (K, N) output
    tile stays resident while its row-blocks accumulate into it; the
    offsets predicate skips blocks outside [offs[e], offs[e+1]).
    """
    M, K = lhs.shape
    _, N = g.shape
    E = offsets.shape[0] - 1
    bm = min(block_m, M)
    pad = (-M) % bm
    if pad:
        lhs = jnp.concatenate([lhs, jnp.zeros((pad, K), lhs.dtype)])
        g = jnp.concatenate([g, jnp.zeros((pad, N), g.dtype)])
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(E, (M + pad) // bm),
        in_specs=[
            pl.BlockSpec((bm, K), lambda e, i, offs: (i, 0)),
            pl.BlockSpec((bm, N), lambda e, i, offs: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, K, N), lambda e, i, offs: (e, 0, 0)),
    )
    return pl.pallas_call(
        functools.partial(_grouped_drhs_kernel, block_m=bm),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((E, K, N), jnp.float32),
        interpret=interpret,
    )(offsets.astype(jnp.int32), lhs, g)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def grouped_matmul(lhs: jax.Array, rhs: jax.Array, group_sizes: jax.Array,
                   interpret: bool = True,
                   block_m: int = DEFAULT_BLOCK_M) -> jax.Array:
    """y (M, N) with y[seg_e] = lhs[seg_e] @ rhs[e] per expert segment.

    lhs (M, K) expert-sorted rows, rhs (E, K, N), group_sizes (E,).
    Rows past sum(group_sizes) produce zeros.
    """
    return _grouped_fwd(lhs, rhs, group_sizes, interpret, block_m)[0]


def _grouped_fwd(lhs, rhs, group_sizes, interpret, block_m):
    offs = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                            jnp.cumsum(group_sizes).astype(jnp.int32)])
    out = _grouped_matmul_impl(lhs, rhs, offs, interpret=interpret,
                               block_m=block_m)
    return out, (lhs, rhs, offs)


def _grouped_bwd(interpret, block_m, res, g):
    # Both gradients are Pallas kernels off the residuals — NO forward
    # recompute (the old path re-ran the whole forward through jax.vjp of
    # ragged_dot just to reach its transpose rule):
    #   dlhs[seg_e] = g[seg_e] @ rhs[e]ᵀ  — the forward kernel with its
    #                                       (K, N) tile transposed in-kernel
    #   drhs[e]     = lhs[seg_e]ᵀ @ g[seg_e]  — segment outer-product sum
    lhs, rhs, offs = res
    g = g.astype(lhs.dtype)
    dlhs = _grouped_matmul_impl(g, rhs, offs, transpose_rhs=True,
                                interpret=interpret, block_m=block_m)
    drhs = _grouped_drhs_impl(lhs, g, offs,
                              interpret=interpret, block_m=block_m)
    return dlhs.astype(lhs.dtype), drhs.astype(rhs.dtype), None


grouped_matmul.defvjp(_grouped_fwd, _grouped_bwd)


def grouped_ffn(params: Dict[str, jax.Array], xs: jax.Array,
                group_sizes: jax.Array, act: str, *,
                use_pallas: bool = False, interpret: bool = True,
                block_m: int = DEFAULT_BLOCK_M) -> jax.Array:
    """Expert FFN over the expert-sorted (M, d) buffer — dropless twin of
    ``moe.expert_ffn``.  w_up/w_gate/w_out have leading dim E; their f
    dim may be a TP slice (see module docstring) — the output is then a
    partial sum the caller must reduce over the TP axis."""
    if "w_gate" in params and params["w_gate"].shape != params["w_up"].shape:
        # a mixed TP/unsliced param tree would silently produce a wrong
        # elementwise swiglu on the narrower slice
        raise ValueError(
            f"grouped_ffn: w_gate shape {params['w_gate'].shape} != w_up "
            f"shape {params['w_up'].shape} — up/gate must carry the same "
            f"(E, d, f) slice (expert-TP shards both on f together)")
    if use_pallas:
        mm = functools.partial(grouped_matmul, interpret=interpret,
                               block_m=block_m)
    else:
        def mm(l, r, sizes):
            # f32 accumulation, rounded back per matmul — matches the
            # sort path's einsum precision in bf16.  The f32 compute is
            # expressed as input casts, NOT preferred_element_type: the
            # ragged_dot transpose emits cotangents in the ACCUMULATE
            # dtype, and that f32 leak into a bf16 graph trips the
            # lowering verifier once TP collectives surround it (the
            # cast form transposes dtype-soundly; bwd dtypes asserted
            # in tests).
            dt = l.dtype
            return lax.ragged_dot(l.astype(jnp.float32),
                                  r.astype(jnp.float32), sizes).astype(dt)
    h = mm(xs, params["w_up"], group_sizes)
    if act in ("swiglu", "geglu"):
        gt = mm(xs, params["w_gate"], group_sizes)
        h = h * (jax.nn.silu(gt) if act == "swiglu" else jax.nn.gelu(gt))
    elif act == "gelu":
        h = jax.nn.gelu(h)
    else:
        h = jax.nn.relu(h)
    return mm(h, params["w_out"], group_sizes)
