"""Flash attention (fwd + bwd) as Pallas TPU kernels — beyond-paper §Perf.

The dry-run roofline shows every train/prefill pair memory-bound, with
the (Q, S) attention score tensors' HBM round-trips the single largest
traffic source (dbrx train_4k: ~4 TB/dev/step).  HetuMoE doesn't touch
attention ("expert networks exist in common models"); we do — the
standard online-softmax tiling keeps scores VMEM-resident.

Kernel layout (head-major):
  q (B, H, Sq, d), k/v (B, KV, Sk, d); GQA handled by the k/v BlockSpec
  index map ``h → h // (H // KV)`` — no materialized head expansion.
  Grid (B, H, nq, nk), sequential in nk: online-softmax accumulators
  (o_acc f32, running max m, sum l) live in VMEM scratch across the nk
  steps; the output block is written at the last step.  Causal + window
  masks come from explicit q/k position vectors (prefetch-style inputs),
  so SEQUENCE-SHARDED q (context parallelism) works: each model-rank
  computes its q slice against the full k/v.

Backward: standard two-kernel flash bwd (dq over (nq, nk) grid; dk/dv
over (nk, G, nq) accumulating across the query heads of each kv head),
using the saved per-row logsumexp and the precomputed Δ = rowsum(dO∘O).
Supports the gemma2 attn-logit softcap (tanh recomputed blockwise, its
derivative applied in ds).

Validated in interpret mode against the pure-jnp oracle (ref.py) over
shape/dtype/mask sweeps; see tests/test_flash_attention.py.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _mask(q_pos, k_pos, causal, window):
    m = (k_pos >= 0)[None, :]
    if causal:
        m = m & (k_pos[None, :] <= q_pos[:, None])
    if window is not None:
        m = m & (k_pos[None, :] > q_pos[:, None] - window)
    return m


def _fwd_kernel(qp_ref, kp_ref, q_ref, k_ref, v_ref, o_ref, lse_ref,
                o_acc, m_acc, l_acc, *, scale, causal, window, cap, nk):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        o_acc[...] = jnp.zeros_like(o_acc)
        m_acc[...] = jnp.full_like(m_acc, NEG)
        l_acc[...] = jnp.zeros_like(l_acc)

    q = q_ref[0, 0].astype(jnp.float32)                  # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)
    s = (q @ k.T) * scale                                 # (bq, bk)
    if cap is not None:
        s = cap * jnp.tanh(s / cap)
    s = jnp.where(_mask(qp_ref[...], kp_ref[...], causal, window), s, NEG)
    m_prev = m_acc[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_acc[...] = l_acc[...] * alpha + jnp.sum(p, axis=-1)
    o_acc[...] = o_acc[...] * alpha[:, None] + p @ v
    m_acc[...] = m_new

    @pl.when(ik == nk - 1)
    def _done():
        l = l_acc[...]
        o_ref[0, 0] = (o_acc[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0] = m_acc[...] + jnp.log(l)


def _bwd_dq_kernel(qp_ref, kp_ref, q_ref, k_ref, v_ref, do_ref, lse_ref,
                   delta_ref, dq_ref, dq_acc, *, scale, causal, window,
                   cap, nk):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    s_raw = (q @ k.T) * scale
    if cap is not None:
        t = jnp.tanh(s_raw / cap)
        s = cap * t
    else:
        s = s_raw
    msk = _mask(qp_ref[...], kp_ref[...], causal, window)
    s = jnp.where(msk, s, NEG)
    p = jnp.exp(s - lse_ref[0, 0][:, None])
    dp = do @ v.T
    ds = p * (dp - delta_ref[0, 0][:, None])
    if cap is not None:
        ds = ds * (1.0 - t * t)
    ds = jnp.where(msk, ds, 0.0)
    dq_acc[...] += (ds @ k) * scale

    @pl.when(ik == nk - 1)
    def _done():
        dq_ref[0, 0] = dq_acc[...].astype(dq_ref.dtype)


def _bwd_dkv_kernel(qp_ref, kp_ref, q_ref, k_ref, v_ref, do_ref, lse_ref,
                    delta_ref, dk_ref, dv_ref, dk_acc, dv_acc, *, scale,
                    causal, window, cap, ng, nq):
    g = pl.program_id(3)
    iq = pl.program_id(4)

    @pl.when((g == 0) & (iq == 0))
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q = q_ref[0, 0].astype(jnp.float32)                  # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    s_raw = (q @ k.T) * scale
    if cap is not None:
        t = jnp.tanh(s_raw / cap)
        s = cap * t
    else:
        s = s_raw
    msk = _mask(qp_ref[...], kp_ref[...], causal, window)
    s = jnp.where(msk, s, NEG)
    p = jnp.exp(s - lse_ref[0, 0][:, None])              # (bq, bk)
    dv_acc[...] += p.T @ do
    dp = do @ v.T
    ds = p * (dp - delta_ref[0, 0][:, None])
    if cap is not None:
        ds = ds * (1.0 - t * t)
    ds = jnp.where(msk, ds, 0.0)
    dk_acc[...] += (ds.T @ q) * scale

    @pl.when((g == ng - 1) & (iq == nq - 1))
    def _done():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


def _blocks(S, want):
    b = min(want, S)
    while S % b:
        b -= 1
    return b


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9, 10))
def flash_attention(q, k, v, q_pos, k_pos, scale: float, causal: bool,
                    window: Optional[int], cap: Optional[float],
                    block_q: int = 512, interpret: bool = True):
    """q (B,H,Sq,d), k/v (B,KV,Sk,d), positions i32 (Sq,)/(Sk,) →
    o (B,H,Sq,d).  k_pos < 0 marks invalid slots."""
    o, _ = _flash_fwd(q, k, v, q_pos, k_pos, scale, causal, window, cap,
                      block_q, interpret)
    return o


def _flash_fwd(q, k, v, q_pos, k_pos, scale, causal, window, cap,
               block_q, interpret):
    B, H, Sq, d = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    bq = _blocks(Sq, block_q)
    bk = _blocks(Sk, block_q)
    nq, nk = Sq // bq, Sk // bk
    grid = (B, H, nq, nk)
    kv_map = lambda b, h, iq, ik: (b, h // G, ik, 0)
    o, lse = _scoped(pl.pallas_call(
        functools.partial(_fwd_kernel, scale=scale, causal=causal,
                          window=window, cap=cap, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq,), lambda b, h, iq, ik: (iq,)),
            pl.BlockSpec((bk,), lambda b, h, iq, ik: (ik,)),
            pl.BlockSpec((1, 1, bq, d), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, d), kv_map),
            pl.BlockSpec((1, 1, bk, d), kv_map),
        ],
        out_specs=(
            pl.BlockSpec((1, 1, bq, d), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, iq, ik: (b, h, iq)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((B, H, Sq, d), q.dtype),
            jax.ShapeDtypeStruct((B, H, Sq), jnp.float32),
        ),
        scratch_shapes=[
            pltpu_scratch((bq, d)), pltpu_scratch((bq,)), pltpu_scratch((bq,)),
        ],
        interpret=interpret,
    ), q_pos, k_pos, q, k, v)
    return o, lse


def pltpu_scratch(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)


def _scoped(fn, *operands):
    """Trace a pallas_call under the "pallas_vmem" name scope: the HLO
    analyzer treats those ops as VMEM-resident (only block DMAs count as
    HBM traffic) — matching what the Mosaic kernel does on real TPU."""
    with jax.named_scope("pallas_vmem"):
        return fn(*operands)


def _fa_fwd(q, k, v, q_pos, k_pos, scale, causal, window, cap, block_q,
            interpret):
    o, lse = _flash_fwd(q, k, v, q_pos, k_pos, scale, causal, window, cap,
                        block_q, interpret)
    return o, (q, k, v, q_pos, k_pos, o, lse)


def _fa_bwd(scale, causal, window, cap, block_q, interpret, res, do):
    q, k, v, q_pos, k_pos, o, lse = res
    B, H, Sq, d = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    bq = _blocks(Sq, block_q)
    bk = _blocks(Sk, block_q)
    nq, nk = Sq // bq, Sk // bk
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32),
                    axis=-1)                              # (B,H,Sq)
    kv_map4 = lambda b, h, iq, ik: (b, h // G, ik, 0)
    dq = _scoped(pl.pallas_call(
        functools.partial(_bwd_dq_kernel, scale=scale, causal=causal,
                          window=window, cap=cap, nk=nk),
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((bq,), lambda b, h, iq, ik: (iq,)),
            pl.BlockSpec((bk,), lambda b, h, iq, ik: (ik,)),
            pl.BlockSpec((1, 1, bq, d), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, d), kv_map4),
            pl.BlockSpec((1, 1, bk, d), kv_map4),
            pl.BlockSpec((1, 1, bq, d), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, iq, ik: (b, h, iq)),
            pl.BlockSpec((1, 1, bq), lambda b, h, iq, ik: (b, h, iq)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu_scratch((bq, d))],
        interpret=interpret,
    ), q_pos, k_pos, q, k, v, do, lse, delta)

    # dk/dv: grid over kv heads and blocks; accumulate across the G query
    # heads of this kv head and all q blocks
    def hmap(b, kv, ik, g, iq):
        return (b, kv * G + g, iq, 0)

    dk, dv = _scoped(pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, scale=scale, causal=causal,
                          window=window, cap=cap, ng=G, nq=nq),
        grid=(B, KV, nk, G, nq),
        in_specs=[
            pl.BlockSpec((bq,), lambda b, kv, ik, g, iq: (iq,)),
            pl.BlockSpec((bk,), lambda b, kv, ik, g, iq: (ik,)),
            pl.BlockSpec((1, 1, bq, d), hmap),
            pl.BlockSpec((1, 1, bk, d), lambda b, kv, ik, g, iq: (b, kv, ik, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b, kv, ik, g, iq: (b, kv, ik, 0)),
            pl.BlockSpec((1, 1, bq, d), hmap),
            pl.BlockSpec((1, 1, bq), lambda b, kv, ik, g, iq: (b, kv * G + g, iq)),
            pl.BlockSpec((1, 1, bq), lambda b, kv, ik, g, iq: (b, kv * G + g, iq)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1, bk, d), lambda b, kv, ik, g, iq: (b, kv, ik, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b, kv, ik, g, iq: (b, kv, ik, 0)),
        ),
        out_shape=(jax.ShapeDtypeStruct(k.shape, k.dtype),
                   jax.ShapeDtypeStruct(v.shape, v.dtype)),
        scratch_shapes=[pltpu_scratch((bk, d)), pltpu_scratch((bk, d))],
        interpret=interpret,
    ), q_pos, k_pos, q, k, v, do, lse, delta)
    return dq, dk, dv, None, None


flash_attention.defvjp(_fa_fwd, _fa_bwd)
