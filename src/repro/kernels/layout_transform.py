"""Layout-transform kernel (paper §3.2 "Layout Transform Optimization", Fig. 4).

HetuMoE's CUDA kernel packs tokens bound for the same expert into
contiguous memory with a warp-per-token gather.  TPU adaptation
(DESIGN.md §2): a scalar-prefetch Pallas gather.  The original port
issued one (1, d) DMA per grid step — the slowest possible tiling; this
version is BLOCKED: each grid step produces a ``(block_m, d)`` output
tile, driven by a ``block_m``-wide slab of the prefetched index vector,
with the source rows resident in VMEM (constant ``index_map`` → fetched
once, not per step).  Rows with idx < 0 are zeroed (dropped slots).

The VJP is the matching BLOCKED scatter-add kernel: the whole ``(N, d)``
accumulator stays resident across grid steps (zeroed on step 0) while
``(block_m, d)`` gradient tiles are scattered into it — the same
layout transform run in the opposite direction.

VMEM note: both kernels keep the full source/accumulator resident, so
``N·d`` must fit on-chip; for larger buffers shard the row dimension
outside the kernel (the MoE layer's per-device buffers are well inside
the budget at paper dims).

Both directions use ONE gather kernel:
  dispatch  out[r] = tokens[inv[r]]   (inv from the plan; -1 → zeros)
  combine   out[s·K+j] = buffer[slot[s,j]]  (then weighted-sum in jnp)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_M = 128


def _pad_len(n: int, block: int) -> int:
    return (-n) % block


def _gather_rows_kernel(idx_ref, src_ref, out_ref, *, block_m: int):
    i = pl.program_id(0)
    slab = idx_ref[pl.ds(i * block_m, block_m)]
    rows = jnp.take(src_ref[...], jnp.maximum(slab, 0), axis=0)
    out_ref[...] = jnp.where((slab >= 0)[:, None], rows, 0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def gather_rows(src: jax.Array, idx: jax.Array, interpret: bool = True,
                block_m: int = DEFAULT_BLOCK_M):
    """out[i] = src[idx[i]] (0 where idx[i] < 0).  src (N, d), idx (M,).

    Differentiable: the VJP is the blocked scatter-add kernel below (on
    TPU that is the same layout-transform run in the opposite direction).
    """
    return _gather_rows_fwd(src, idx, interpret, block_m)[0]


def _gather_rows_fwd(src, idx, interpret, block_m):
    # the (N, 0) token carries src's row count + dtype into the bwd pass
    # (shapes/dtypes are not valid residual leaves themselves)
    token = jnp.zeros((src.shape[0], 0), src.dtype)
    return _gather_rows_impl(src, idx, interpret=interpret,
                             block_m=block_m), (idx, token)


def _gather_rows_bwd(interpret, block_m, res, g):
    idx, token = res
    dsrc = scatter_add_rows(g, idx, token.shape[0], interpret=interpret,
                            block_m=block_m)
    return dsrc.astype(token.dtype), None


gather_rows.defvjp(_gather_rows_fwd, _gather_rows_bwd)


@functools.partial(jax.jit, static_argnames=("interpret", "block_m"))
def _gather_rows_impl(src: jax.Array, idx: jax.Array, *,
                      interpret: bool = True,
                      block_m: int = DEFAULT_BLOCK_M):
    M, = idx.shape
    N, d = src.shape
    bm = min(block_m, M)
    pad = _pad_len(M, bm)
    if pad:
        idx = jnp.concatenate([idx.astype(jnp.int32),
                               jnp.full((pad,), -1, jnp.int32)])
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=((M + pad) // bm,),
        in_specs=[pl.BlockSpec((N, d), lambda i, idx_ref: (0, 0))],
        out_specs=pl.BlockSpec((bm, d), lambda i, idx_ref: (i, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_gather_rows_kernel, block_m=bm),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M + pad, d), src.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), src)
    return out[:M] if pad else out


def _scatter_add_kernel(idx_ref, g_ref, out_ref, *, block_m: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    slab = idx_ref[pl.ds(i * block_m, block_m)]
    n = out_ref.shape[0]
    # idx < 0 → dumped past the accumulator and dropped by mode="drop";
    # duplicate indices accumulate (needed by the general VJP).
    safe = jnp.where(slab >= 0, slab, n)
    out_ref[...] = out_ref[...].at[safe].add(g_ref[...], mode="drop")


@functools.partial(jax.jit, static_argnames=("n", "interpret", "block_m"))
def scatter_add_rows(g: jax.Array, idx: jax.Array, n: int, *,
                     interpret: bool = True,
                     block_m: int = DEFAULT_BLOCK_M) -> jax.Array:
    """out (n, d) with out[idx[i]] += g[i] (idx[i] < 0 skipped)."""
    M, d = g.shape
    bm = min(block_m, M)
    pad = _pad_len(M, bm)
    if pad:
        idx = jnp.concatenate([idx.astype(jnp.int32),
                               jnp.full((pad,), -1, jnp.int32)])
        g = jnp.concatenate([g, jnp.zeros((pad, d), g.dtype)])
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=((M + pad) // bm,),
        in_specs=[pl.BlockSpec((bm, d), lambda i, idx_ref: (i, 0))],
        out_specs=pl.BlockSpec((n, d), lambda i, idx_ref: (0, 0)),
    )
    return pl.pallas_call(
        functools.partial(_scatter_add_kernel, block_m=bm),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, d), g.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), g)


# ---------------------------------------------------------------------------
# seed reference: the original row-per-step tiling, kept for benchmarking
# the blocked kernel against (bench_layout) and as the worst-case bound.
# ---------------------------------------------------------------------------

def _gather_row_kernel(idx_ref, src_ref, out_ref):
    i = pl.program_id(0)
    out_ref[...] = jnp.where(idx_ref[i] >= 0, src_ref[...], 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_rows_rowstep(src: jax.Array, idx: jax.Array, *,
                        interpret: bool = True):
    """One (1, d) DMA per grid step — the seed tiling (do not use on the
    hot path; exists so benchmarks can quantify the blocked kernel's win)."""
    M, = idx.shape
    N, d = src.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(M,),
        in_specs=[pl.BlockSpec((1, d),
                               lambda i, idx_ref: (jnp.maximum(idx_ref[i], 0), 0))],
        out_specs=pl.BlockSpec((1, d), lambda i, idx_ref: (i, 0)),
    )
    return pl.pallas_call(
        _gather_row_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, d), src.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), src)
