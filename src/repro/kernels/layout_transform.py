"""Layout-transform kernel (paper §3.2 "Layout Transform Optimization", Fig. 4).

HetuMoE's CUDA kernel packs tokens bound for the same expert into
contiguous memory with a warp-per-token gather.  TPU adaptation
(DESIGN.md §2): a scalar-prefetch Pallas gather — the row-index vector is
prefetched into SMEM and drives the input ``BlockSpec`` index_map, so each
grid step DMAs exactly the (1, d) row it needs from HBM into VMEM.  This
is the TPU-idiomatic indirection primitive (the same pattern as
sparse-dense matmul gathers); XLA's alternative lowers scatter/gather to
serialized HLO loops.

Both directions use ONE kernel:
  dispatch  out[r] = tokens[inv[r]]   (inv from the plan; -1 → zeros)
  combine   out[s·K+j] = buffer[slot[s,j]]  (then weighted-sum in jnp)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_rows_kernel(idx_ref, src_ref, out_ref):
    # src_ref is the (block, d) slab selected by the index_map below;
    # rows with idx < 0 are zeroed (dropped slots).
    i = pl.program_id(0)
    valid = idx_ref[i] >= 0
    out_ref[...] = jnp.where(valid, src_ref[...], 0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def gather_rows(src: jax.Array, idx: jax.Array, interpret: bool = True):
    """out[i] = src[idx[i]] (0 where idx[i] < 0).  src (N, d), idx (M,).

    Differentiable: the VJP is the inverse scatter-add (on TPU that is the
    same layout-transform run in the opposite direction; indices in a
    dispatch/combine plan are unique so no real collisions occur).
    """
    return _gather_rows_fwd(src, idx, interpret)[0]


def _gather_rows_fwd(src, idx, interpret):
    # the (N, 0) token carries src's row count + dtype into the bwd pass
    # (shapes/dtypes are not valid residual leaves themselves)
    token = jnp.zeros((src.shape[0], 0), src.dtype)
    return _gather_rows_impl(src, idx, interpret=interpret), (idx, token)


def _gather_rows_bwd(interpret, res, g):
    idx, token = res
    n = token.shape[0]
    safe = jnp.where(idx >= 0, idx, n)
    dsrc = jnp.zeros((n, g.shape[1]), g.dtype).at[safe].add(
        jnp.where((idx >= 0)[:, None], g, 0), mode="drop")
    return dsrc.astype(token.dtype), None


gather_rows.defvjp(_gather_rows_fwd, _gather_rows_bwd)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _gather_rows_impl(src: jax.Array, idx: jax.Array, *, interpret: bool = True):
    M, = idx.shape
    N, d = src.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(M,),
        in_specs=[pl.BlockSpec((1, d), lambda i, idx_ref: (jnp.maximum(idx_ref[i], 0), 0))],
        out_specs=pl.BlockSpec((1, d), lambda i, idx_ref: (i, 0)),
    )
    return pl.pallas_call(
        _gather_rows_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, d), src.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), src)
