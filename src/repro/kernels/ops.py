"""Jit'd public wrappers around the Pallas kernels.

``INTERPRET`` is True off-TPU: the kernel bodies execute in Python on CPU
(the container's validation mode); on a real TPU the same code lowers to
Mosaic.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import layout_transform, topk_gate

INTERPRET = jax.default_backend() != "tpu"


def fused_topk(logits: jax.Array, k: int):
    """(vals, idx, rowmax, sumexp) — see kernels/topk_gate.py."""
    return topk_gate.fused_topk_gate(logits, k, interpret=INTERPRET)


def topk_softmax_weights(logits: jax.Array, k: int):
    """Top-k indices + their softmax(logits) probabilities + full probs,
    all derived from the fused kernel's single pass.

    The kernel's ``rowmax`` provides the stable exp shift — softmax is
    shift-invariant, so treating it as a constant keeps the u/Σu jacobian
    exactly the softmax jacobian (the router still trains); only the Σexp
    reduction is redone differentiably.
    """
    logits = logits.astype(jnp.float32)
    _, idx, rowmax, _ = fused_topk(jax.lax.stop_gradient(logits), k)
    u = jnp.exp(logits - jax.lax.stop_gradient(rowmax))
    probs = u / jnp.sum(u, axis=-1, keepdims=True)
    weights = jnp.take_along_axis(probs, idx, axis=-1)
    return idx, weights, probs


def layout_dispatch(tokens: jax.Array, slot: jax.Array,
                    num_experts: int, capacity: int,
                    inv: Optional[jax.Array] = None) -> jax.Array:
    """(S, d), slot (S, K) → (E·C, d) contiguous-per-expert buffer.

    The scatter is re-expressed as a gather over a row map ``inv (E·C,)``;
    the blocked Pallas kernel then moves the d-wide rows — the
    bandwidth-heavy part.  A sort-once :class:`~repro.core.layout
    .DispatchPlan` already carries ``inv``; pass it to skip the
    re-inversion scatter here.
    """
    if inv is None:
        S, K = slot.shape
        flat = slot.reshape(-1)
        tok_idx = jnp.repeat(jnp.arange(S, dtype=jnp.int32), K)
        inv = jnp.full((num_experts * capacity,), -1, jnp.int32)
        inv = inv.at[jnp.where(flat >= 0, flat, num_experts * capacity)].set(
            tok_idx, mode="drop")
    return layout_transform.gather_rows(tokens, inv, INTERPRET)


def layout_combine(buffer: jax.Array, slot: jax.Array,
                   weight: jax.Array) -> jax.Array:
    """Inverse transform: gather rows back per (token, k) and weighted-sum."""
    S, K = slot.shape
    rows = layout_transform.gather_rows(
        buffer, slot.reshape(-1), INTERPRET).reshape(S, K, -1)
    w = (weight * (slot >= 0)).astype(buffer.dtype)
    return jnp.einsum("skd,sk->sd", rows, w)


def gather_rows(src: jax.Array, idx: jax.Array) -> jax.Array:
    """Blocked-kernel row gather (0 where idx < 0) — grouped dispatch."""
    return layout_transform.gather_rows(src, idx, INTERPRET)
