"""Seeded workload-replay harness for the slot-based serving scheduler.

``SlotServer`` was only ever exercised by symmetric smoke workloads; real
traffic is the opposite — Poisson or bursty arrivals, mixed prompt and
output lengths, and adversarially skewed expert routing (the regime
where the grouped path's static bounds and the capacity-padded path's
drops actually bite).  This module replays a *deterministic, seeded*
workload against a ``SlotServer`` and reports the serving numbers that
matter: p50/p99 per-token latency, time-to-first-token, and slot
utilization.

Everything is reproducible from ``TrafficConfig.seed``:

* **arrivals** — ``"poisson"`` draws exponential inter-arrival gaps
  (mean ``1/rate`` decode steps); ``"bursty"`` releases requests in
  bursts of ``burst_size`` every ``burst_every`` steps (the
  queue-pressure worst case for a fixed slot pool);
* **shapes** — prompt lengths and output budgets are drawn per request
  from ``prompt_lens`` / ``max_new_choices``;
* **skew** — :func:`skew_router` biases every MoE router toward one
  expert (adds a large constant to that expert's logit column), the
  adversarial hot-expert distribution HierMoE targets.  It returns a
  modified *copy* of the params, so one param set serves both the
  uniform and the skewed scenario.

The replay clock is the decode-step counter, not wall time — arrivals
are keyed to steps so the workload is identical across machines — while
the reported latencies are wall-clock (what a user would see on this
host).  Per-token latency for a request is (completion − arrival) /
tokens-produced; utilization is the mean over decode steps of
active-slots / total-slots.  Requests that terminate without producing
tokens (rejections, failed prefills) are counted in the report but
excluded from the latency percentiles.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import numpy as np

import jax.numpy as jnp

from repro.core.config import ModelConfig
from repro.serving.scheduler import Request, SlotServer


@dataclass(frozen=True)
class TrafficConfig:
    """One seeded traffic scenario."""
    num_requests: int = 16
    arrival: str = "poisson"            # "poisson" | "bursty"
    rate: float = 0.5                   # poisson: mean arrivals per decode step
    burst_size: int = 4                 # bursty: requests per burst
    burst_every: int = 8                # bursty: steps between bursts
    prompt_lens: Tuple[int, ...] = (4, 6, 8)
    max_new_choices: Tuple[int, ...] = (3, 5, 8)
    seed: int = 0

    ARRIVALS = ("poisson", "bursty")

    def __post_init__(self):
        if self.arrival not in self.ARRIVALS:
            raise ValueError(
                f"TrafficConfig.arrival={self.arrival!r} not in "
                f"{self.ARRIVALS}")
        if self.num_requests < 1:
            raise ValueError(
                f"TrafficConfig.num_requests must be >= 1, got "
                f"{self.num_requests}")


@dataclass
class TrafficReport:
    """Replay outcome.  Latencies in wall-clock seconds; the step counts
    are the deterministic (machine-independent) shape of the run."""
    completed: int = 0
    rejected: int = 0
    failed: int = 0
    evicted: int = 0
    decode_steps: int = 0
    p50_per_token_s: float = float("nan")
    p99_per_token_s: float = float("nan")
    p50_first_token_s: float = float("nan")
    p99_first_token_s: float = float("nan")
    slot_utilization: float = 0.0
    tokens_out: int = 0
    wall_s: float = 0.0
    statuses: Dict[int, str] = field(default_factory=dict)

    def summary(self) -> str:
        return (f"completed={self.completed} rejected={self.rejected} "
                f"failed={self.failed} evicted={self.evicted} "
                f"steps={self.decode_steps} util={self.slot_utilization:.2f} "
                f"p50/tok={self.p50_per_token_s * 1e3:.2f}ms "
                f"p99/tok={self.p99_per_token_s * 1e3:.2f}ms")


def synthesize_workload(tc: TrafficConfig, cfg: ModelConfig
                        ) -> List[Tuple[int, Request]]:
    """Deterministic ``[(arrival_step, Request)]``, sorted by arrival.
    Token ids draw uniformly from the model vocab; the same
    ``(TrafficConfig, vocab)`` always yields the same workload."""
    rng = np.random.default_rng(tc.seed)
    arrivals: List[int] = []
    if tc.arrival == "poisson":
        t = 0.0
        for _ in range(tc.num_requests):
            t += rng.exponential(1.0 / max(tc.rate, 1e-6))
            arrivals.append(int(t))
    else:                               # bursty
        step = 0
        while len(arrivals) < tc.num_requests:
            n = min(tc.burst_size, tc.num_requests - len(arrivals))
            arrivals.extend([step] * n)
            step += tc.burst_every
    out = []
    for uid, at in enumerate(arrivals):
        n = int(rng.choice(tc.prompt_lens))
        prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(n,)),
                             jnp.int32)
        req = Request(uid=uid, prompt=prompt,
                      max_new=int(rng.choice(tc.max_new_choices)))
        out.append((at, req))
    out.sort(key=lambda p: p[0])
    return out


def skew_router(params, bias: float = 16.0, expert: int = 0):
    """Adversarially skew every MoE router toward ``expert`` by adding
    ``bias`` to that expert's logit column (gate logits are O(1) at
    init, so 16 wins every top-k comparison).  Returns a new params
    tree; the input is untouched."""

    def walk(p):
        if isinstance(p, dict):
            out = {}
            for k, v in p.items():
                if k == "moe" and isinstance(v, dict) and "gate_w" in v:
                    gw = v["gate_w"]
                    v = {**v, "gate_w": gw.at[..., expert].add(
                        jnp.asarray(bias, gw.dtype))}
                else:
                    v = walk(v)
                out[k] = v
            return out
        if isinstance(p, (tuple, list)):
            return type(p)(walk(v) for v in p)
        return p

    return walk(params)


def replay(server: SlotServer, workload: List[Tuple[int, Request]],
           *, max_steps: int = 10_000) -> TrafficReport:
    """Drive ``server`` through ``workload``.

    The loop advances one decode step per iteration (idle iterations —
    nothing active yet — still advance the arrival clock, modeling the
    server waiting for traffic).  Admission reuses the server's bounded
    queue and alignment-gated refill; rejected requests are final.
    """
    pending = list(workload)
    arrival_wall: Dict[int, float] = {}
    first_tok_wall: Dict[int, float] = {}
    done: List[Request] = []
    util_samples: List[float] = []
    t_start = time.perf_counter()
    step = 0
    while pending or server.queue or server.active:
        if step >= max_steps:
            raise RuntimeError(
                f"traffic replay exceeded max_steps={max_steps} "
                f"({len(pending)} pending, {len(server.queue)} queued, "
                f"{len(server.active)} active)")
        while pending and pending[0][0] <= step:
            _, req = pending.pop(0)
            arrival_wall[req.uid] = time.perf_counter()
            if not server.enqueue(req):
                done.append(req)        # validation / queue_full rejection
        had_first = {r.uid for r in server.active.values() if r.out}
        done += server.pump()
        now = time.perf_counter()
        for r in server.active.values():
            # prefill emits the first token; stamp it once
            if r.out and r.uid not in had_first and r.uid not in first_tok_wall:
                first_tok_wall[r.uid] = now
        util_samples.append(len(server.active) / server.slots)
        finished = server.step()
        now = time.perf_counter()
        for r in finished:
            r._finish_wall = now        # stashed for the percentile pass
        done += finished
        step += 1

    rep = TrafficReport(decode_steps=step, wall_s=time.perf_counter() - t_start)
    per_tok, first = [], []
    for r in done:
        rep.statuses[r.uid] = r.status
        rep.tokens_out += len(r.out)
        if r.status == "ok":
            rep.completed += 1
        elif r.status == "rejected":
            rep.rejected += 1
        elif r.status == "failed":
            rep.failed += 1
        elif r.status == "evicted":
            rep.evicted += 1
        end = getattr(r, "_finish_wall", None)
        start = arrival_wall.get(r.uid)
        if r.out and start is not None and end is not None:
            per_tok.append((end - start) / len(r.out))
        if r.uid in first_tok_wall and start is not None:
            first.append(first_tok_wall[r.uid] - start)
    if per_tok:
        rep.p50_per_token_s = float(np.percentile(per_tok, 50))
        rep.p99_per_token_s = float(np.percentile(per_tok, 99))
    if first:
        rep.p50_first_token_s = float(np.percentile(first, 50))
        rep.p99_first_token_s = float(np.percentile(first, 99))
    if util_samples:
        rep.slot_utilization = float(np.mean(util_samples))
    return rep
