from repro.serving.engine import (build_decode, build_prefill,
                                  build_slot_prefill, clear_step_cache,
                                  generate, make_prefill_step,
                                  make_serve_step, serve_config,
                                  validate_decode_config)
from repro.serving.scheduler import Request, SlotServer
from repro.serving.traffic import (TrafficConfig, TrafficReport, replay,
                                   skew_router, synthesize_workload)
