from repro.serving.engine import make_prefill_step, make_serve_step, generate
from repro.serving.scheduler import Request, SlotServer
