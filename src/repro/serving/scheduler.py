"""Continuous-batching serving scheduler (slot-based) with overload
degradation.

The decode dry-run shapes assume a full static batch; a real server
receives ragged requests.  This scheduler keeps a fixed-size slot pool
over ONE compiled ``serve_step`` (static shapes — no retraces): arriving
requests claim free slots via per-slot prefill into the shared batched
cache; finished/evicted slots are refilled mid-flight.  Per-slot cache
insertion uses a batched dynamic-update along the batch axis, so the hot
decode loop never recompiles.

Fault tolerance / overload degradation:

* **admission**: requests are validated up front (prompt length vs
  ``cache_len``, token range vs the vocab, ``max_new``) and rejected with
  a structured status instead of corrupting the shared batched cache —
  an oversized prompt previously scribbled past its slot via
  ``dynamic_update_slice``;
* **backpressure**: a bounded admission queue (``queue_limit``) rejects
  with ``status="rejected", error="queue_full"`` once full, so one burst
  cannot grow host memory without bound;
* **poisoned-request containment**: a prefill that raises or yields
  non-finite logits marks THAT request ``failed`` and frees the slot
  without committing its cache writes; a slot whose decode logits go
  non-finite is likewise failed and freed while the rest of the batch
  keeps decoding;
* **deadlines**: ``Request.deadline_steps`` (or the server-wide
  ``default_deadline_steps``) evicts a request after that many decode
  steps, bounding the time one slot can be held (``max_new`` already
  bounds the token budget).

Aligned refill: the per-layer decode caches carry ONE scalar ``pos``
shared by every slot, and a prefill resets it to the new prompt's length
— so an unaligned mid-flight prefill silently corrupts every other
in-flight slot's attention mask and rope positions (the seed's scheduler
only survived because its smoke test used symmetric requests that finish
together).  Until the caches grow per-slot positions, admission is gated
on alignment: a queued request is prefilled only when no slot is active
(pos resets cleanly) or its prompt length equals the current shared pos
(the reset is a no-op).  The queue is scanned first-fit, so an aligned
request behind a misaligned head still gets its slot.

Compiled steps come from the ``serving/engine.py`` step-builder cache
(``build_decode`` / ``build_slot_prefill``) — the scheduler never calls
``jax.jit`` itself, so two servers over the same ``(cfg, cache_len,
slots)`` share one compiled step, and the decode dispatch mode is a
first-class constructor argument (``dispatch="grouped"`` routes the
tiny, ragged decode batches through dropless grouped compute — the
supported serving configuration; the override is validated against
``DISPATCH_MODES``, never silently rewritten).  Grouped-path bounds are
validated at server CONSTRUCTION time (``engine.validate_decode_config``),
not at first-trace time.

Fault-injection seams (``core/faults.py``): ``serve.prefill`` /
``serve.prefill_logits`` (indexed by request uid), ``serve.step_logits``
(uid), ``serve.step`` (decode-step counter; ``stall`` mode simulates a
slow step without wall-clock flakiness — deadlines count steps, not
seconds), and ``serve.decode_row`` (decode-step counter) — delivered
inside the step-builder path (``engine.build_decode``), poisoning one
seeded element of the batched decode logits: the grouped-decode-row
containment case, proving one poisoned row fails only its own slot.

CPU-scale but structurally the production pattern (vLLM-style slots
without paging — the ring/linear caches are contiguous per slot).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

import numpy as np

import jax.numpy as jnp

from repro.core import faults as faults_mod
from repro.core.config import ModelConfig
from repro.models import transformer as T
from repro.serving import engine

# terminal request statuses (Request.done=True implies one of these)
TERMINAL_STATUSES = ("ok", "rejected", "failed", "evicted")


@dataclass
class Request:
    uid: int
    prompt: jnp.ndarray              # (S,) int32
    max_new: int
    out: List[int] = field(default_factory=list)
    done: bool = False
    status: str = "pending"          # pending|queued|active|ok|rejected|failed|evicted
    error: Optional[str] = None      # structured rejection/failure reason
    deadline_steps: Optional[int] = None  # decode-step budget (None = server default)
    steps_used: int = 0              # decode steps consumed while active


class SlotServer:
    """Fixed-slot continuous batching over one compiled serve_step."""

    def __init__(self, cfg: ModelConfig, params, *, slots: int,
                 cache_len: int, mesh=None, eos_id: Optional[int] = None,
                 queue_limit: Optional[int] = None,
                 default_deadline_steps: Optional[int] = None,
                 dispatch: Optional[str] = None):
        assert cfg.has_decode and cfg.frontend is None
        if queue_limit is not None and queue_limit < 1:
            raise ValueError(
                f"SlotServer queue_limit must be >= 1 or None (unbounded), "
                f"got {queue_limit}")
        cfg = engine.serve_config(cfg, dispatch=dispatch)
        # fail HERE, at server construction, not at the first decode
        # trace: grouped bounds / overlap divisibility / a2a divisibility
        engine.validate_decode_config(cfg, mesh, slots, cache_len=cache_len)
        self.cfg, self.params, self.mesh = cfg, params, mesh
        self.slots = slots
        self.cache_len = cache_len
        self.eos_id = eos_id
        self.queue_limit = queue_limit
        self.default_deadline_steps = default_deadline_steps
        self.caches = T.init_caches(cfg, slots, cache_len,
                                    dtype=jnp.dtype(cfg.dtype))
        self.active: Dict[int, Request] = {}          # slot → request
        self.queue: Deque[Request] = deque()          # admitted, awaiting a slot
        self.tokens = jnp.zeros((slots, 1), jnp.int32)
        self._decode_steps = 0
        self._pos = 0            # host mirror of the caches' shared pos scalar
        # compiled steps from the shared builder cache — two servers over
        # the same (cfg, mesh, cache_len, slots) reuse one traced step
        self._step = engine.build_decode(cfg, mesh, batch=slots)
        self._prefill = engine.build_slot_prefill(cfg, mesh,
                                                  cache_len=cache_len)

    # -- validation / admission ---------------------------------------------
    def _validate(self, req: Request) -> Optional[str]:
        """Structured rejection reason, or None if admissible."""
        n = int(np.asarray(req.prompt).shape[-1]) if req.prompt.ndim else 0
        if req.prompt.ndim != 1 or n < 1:
            return f"bad_prompt_shape:{tuple(req.prompt.shape)}"
        # prefill writes n cache rows and every decode step writes one
        # more; n > cache_len - 1 would scribble past the slot's cache
        if n > self.cache_len - 1:
            return f"prompt_too_long:{n}>cache_len-1={self.cache_len - 1}"
        toks = np.asarray(req.prompt)
        if toks.min() < 0 or toks.max() >= self.cfg.vocab_size:
            return (f"token_out_of_range:[{int(toks.min())},"
                    f"{int(toks.max())}]∉[0,{self.cfg.vocab_size})")
        if req.max_new < 1:
            return f"bad_max_new:{req.max_new}"
        return None

    def _reject(self, req: Request, reason: str) -> None:
        req.status, req.error, req.done = "rejected", reason, True

    def enqueue(self, req: Request) -> bool:
        """Admit into the bounded queue.  False = terminally rejected
        (validation failure, or backpressure when the queue is full)."""
        reason = self._validate(req)
        if reason is not None:
            self._reject(req, reason)
            return False
        if self.queue_limit is not None and len(self.queue) >= self.queue_limit:
            self._reject(req, "queue_full")
            return False
        req.status = "queued"
        self.queue.append(req)
        return True

    def _aligned(self, req: Request) -> bool:
        """True when prefilling ``req`` now cannot corrupt in-flight
        slots: either no slot is active (the shared pos resets cleanly)
        or the prompt length equals the current shared pos (the reset is
        a no-op).  See the module docstring."""
        return not self.active or int(req.prompt.shape[-1]) == self._pos

    def _admit(self, req: Request, slot: int) -> bool:
        """Prefill into ``slot``.  A prefill that raises or yields
        non-finite logits fails the request WITHOUT committing its cache
        writes (the slot stays clean for the next request).  True =
        the slot is now occupied."""
        try:
            faults_mod.crash_point("serve.prefill", index=req.uid)
            logits, new_caches = self._prefill(self.params,
                                               req.prompt[None, :],
                                               self.caches, slot)
            lg = faults_mod.inject_array("serve.prefill_logits", logits,
                                         index=req.uid)
            if not np.all(np.isfinite(lg)):
                raise faults_mod.FaultInjected("non-finite prefill logits")
        except Exception as e:  # containment: poisoned request, not the server
            req.status, req.error, req.done = "failed", f"prefill:{e}", True
            return False
        tok = int(np.argmax(lg))
        self.caches = new_caches
        self._pos = int(req.prompt.shape[-1])
        self.tokens = self.tokens.at[slot, 0].set(tok)
        req.out.append(tok)
        req.status = "active"
        self.active[slot] = req
        return True

    # -- public API ---------------------------------------------------------
    def submit(self, req: Request) -> bool:
        """Claim a free slot directly (legacy API).  False = no slot can
        take the request right now (pool full, or refill not aligned —
        retry later); True = the request was consumed: admitted, or
        terminally rejected/failed (check ``req.status``)."""
        reason = self._validate(req)
        if reason is not None:
            self._reject(req, reason)
            return True
        if not self._aligned(req):
            return False
        for s in range(self.slots):
            if s not in self.active:
                self._admit(req, s)   # failed prefill still consumes req
                return True
        return False

    def pump(self) -> List[Request]:
        """Move queued requests into free slots (first-fit over the queue
        — only alignment-safe refills, see ``_aligned``); returns
        requests that terminally failed during prefill."""
        failed = []
        for s in range(self.slots):
            if s in self.active:
                continue
            for req in list(self.queue):
                if not self._aligned(req):
                    continue
                self.queue.remove(req)
                if self._admit(req, s):
                    break
                failed.append(req)
        return failed

    def _deadline(self, req: Request) -> Optional[int]:
        return (req.deadline_steps if req.deadline_steps is not None
                else self.default_deadline_steps)

    def step(self) -> List[Request]:
        """One batched decode step for every active slot; returns newly
        finished requests — ok, failed (non-finite logits) or evicted
        (deadline) — with their slots freed."""
        if not self.active:
            return []
        faults_mod.maybe_stall("serve.step", index=self._decode_steps)
        logits, self.caches = self._step(self.params, self.tokens, self.caches,
                                         step_index=self._decode_steps)
        self._decode_steps += 1
        self._pos += 1
        lg = np.asarray(logits[:, -1].astype(jnp.float32))
        finished = []
        next_tokens = np.asarray(self.tokens).copy()
        for s, req in list(self.active.items()):
            row = faults_mod.inject_array("serve.step_logits", lg[s],
                                          index=req.uid)
            req.steps_used += 1
            if not np.all(np.isfinite(row)):
                # poisoned mid-decode: fail THIS request, free the slot —
                # its cache line is fully overwritten by the next prefill,
                # so the other slots never see the damage
                req.status, req.error, req.done = \
                    "failed", "non_finite_decode_logits", True
                finished.append(req)
                del self.active[s]
                continue
            tok = int(np.argmax(row))
            next_tokens[s, 0] = tok
            req.out.append(tok)
            dl = self._deadline(req)
            if len(req.out) >= req.max_new or (self.eos_id is not None
                                               and tok == self.eos_id):
                req.status, req.done = "ok", True
                finished.append(req)
                del self.active[s]
            elif dl is not None and req.steps_used >= dl:
                req.status, req.error, req.done = "evicted", "deadline", True
                finished.append(req)
                del self.active[s]
        self.tokens = jnp.asarray(next_tokens)
        return finished

    def run(self, requests: List[Request]) -> List[Request]:
        """Drive a request list to completion with continuous refill.
        Returns EVERY request once terminal (``ok``/``rejected``/
        ``failed``/``evicted``) — a mixed workload with oversized or
        poisoned requests still drains the healthy ones."""
        pending = list(requests)
        done: List[Request] = []
        while pending or self.queue or self.active:
            # feed with backpressure: only hand the queue what it has room
            # for, so a huge batch never trips its own queue_limit
            while pending and (self.queue_limit is None
                               or len(self.queue) < self.queue_limit):
                req = pending.pop(0)
                if not self.enqueue(req):
                    done.append(req)          # validation rejection
            done += self.pump()               # prefill failures
            done += self.step()
        return done
