"""Continuous-batching serving scheduler (slot-based).

The decode dry-run shapes assume a full static batch; a real server
receives ragged requests.  This scheduler keeps a fixed-size slot pool
over ONE compiled ``serve_step`` (static shapes — no retraces): arriving
requests claim free slots via per-slot prefill into the shared batched
cache; finished/evicted slots are refilled mid-flight.  Per-slot cache
insertion uses a batched dynamic-update along the batch axis, so the hot
decode loop never recompiles.

CPU-scale but structurally the production pattern (vLLM-style slots
without paging — the ring/linear caches are contiguous per slot).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig
from repro.models import transformer as T
from repro.serving.engine import make_serve_step


@dataclass
class Request:
    uid: int
    prompt: jnp.ndarray              # (S,) int32
    max_new: int
    out: List[int] = field(default_factory=list)
    done: bool = False


class SlotServer:
    """Fixed-slot continuous batching over one compiled serve_step."""

    def __init__(self, cfg: ModelConfig, params, *, slots: int,
                 cache_len: int, mesh=None, eos_id: Optional[int] = None):
        assert cfg.has_decode and cfg.frontend is None
        self.cfg, self.params, self.mesh = cfg, params, mesh
        self.slots = slots
        self.cache_len = cache_len
        self.eos_id = eos_id
        self.caches = T.init_caches(cfg, slots, cache_len,
                                    dtype=jnp.dtype(cfg.dtype))
        self.active: Dict[int, Request] = {}          # slot → request
        self.tokens = jnp.zeros((slots, 1), jnp.int32)
        self._step = jax.jit(make_serve_step(cfg, mesh))
        # per-slot prefill: full-batch forward on a (1, S) prompt, then
        # scatter its caches into slot i of the batched cache tree
        self._prefill = jax.jit(self._prefill_impl, static_argnums=(2,))

    def _prefill_impl(self, prompt, caches, slot):
        sub = T.init_caches(self.cfg, 1, self.cache_len,
                            dtype=jnp.dtype(self.cfg.dtype))
        h, _, sub = T.forward(self.params, prompt, self.cfg, mesh=self.mesh,
                              caches=sub, collect_caches=True)
        logits = T.logits_from_hidden(self.params, self.cfg, h[:, -1:],
                                      self.mesh)

        def put(full, one):
            if one.ndim >= 2 and one.shape[1] == 1:     # (NSB, 1, ...) batch
                return jax.lax.dynamic_update_slice(
                    full, one.astype(full.dtype),
                    (0, slot) + (0,) * (full.ndim - 2))
            return one.astype(full.dtype)               # scalars (pos)

        return jnp.argmax(logits[0, -1]), jax.tree.map(put, caches, sub)

    # -- public API ---------------------------------------------------------
    def submit(self, req: Request) -> bool:
        """Claim a free slot; False if the pool is full."""
        for s in range(self.slots):
            if s not in self.active:
                tok, self.caches = self._prefill(req.prompt[None, :],
                                                 self.caches, s)
                self.tokens = self.tokens.at[s, 0].set(tok)
                req.out.append(int(tok))
                self.active[s] = req
                return True
        return False

    def step(self) -> List[Request]:
        """One batched decode step for every active slot; returns newly
        finished requests (their slots are freed)."""
        if not self.active:
            return []
        logits, self.caches = self._step(self.params, self.tokens, self.caches)
        self.tokens = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        finished = []
        for s, req in list(self.active.items()):
            tok = int(self.tokens[s, 0])
            req.out.append(tok)
            if len(req.out) >= req.max_new or (self.eos_id is not None
                                               and tok == self.eos_id):
                req.done = True
                finished.append(req)
                del self.active[s]
        return finished

    def run(self, requests: List[Request]) -> List[Request]:
        """Drive a request list to completion with continuous refill."""
        pending = list(requests)
        done: List[Request] = []
        while pending or self.active:
            while pending and self.submit(pending[0]):
                pending.pop(0)
            done += self.step()
        return done
