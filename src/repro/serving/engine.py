"""Serving: prefill + batched single-token decode steps, built through
ONE step-builder with a process-wide compiled-step cache.

Step-builder / cache contract
-----------------------------

Every serving entrypoint (``generate`` here, ``SlotServer`` in
``serving/scheduler.py``, the ``launch/serve.py`` CLI, the decode
benchmarks) obtains its compiled steps from the builders below instead
of calling ``jax.jit`` on fresh closures:

* ``build_prefill(cfg, mesh, cache_len=, batch=, long_context=)`` —
  jitted ``(params, tokens) -> (last_logits, caches)``;
* ``build_decode(cfg, mesh, batch=, long_context=)`` — jitted
  ``(params, token, caches[, step_index=]) -> (logits, caches)``;
* ``build_slot_prefill(cfg, mesh, cache_len=)`` — jitted
  ``(params, prompt(1,S), caches, slot) -> (last_logits, caches)`` with
  ``slot`` static (the ``SlotServer`` per-slot cache scatter).

Each builder returns the SAME callable for the same cache key
``(kind, cfg, mesh, cache_len, batch, long_context)`` — ``ModelConfig``
is a frozen (hashable) dataclass, so the key captures the dispatch mode
and every other knob — which means a second ``generate()`` call with
identical shapes reuses the already-traced computation instead of
re-jitting a fresh closure per invocation (the seed behaviour, which
recompiled every benchmark/test call).  ``trace_counts`` counts actual
retraces per key; tests probe it to assert cache hits.

* ``serve_config(cfg, dispatch=)`` derives the serving config: the MoE
  dispatch mode override is validated against ``DISPATCH_MODES`` (a
  ``ValueError`` naming the valid modes, never a silent fallback) —
  ``dispatch="grouped"`` is the supported decode configuration: decode
  batches are tiny, ragged, and latency-bound, exactly where capacity
  padding hurts most and dropless grouped compute pays off.
* ``validate_decode_config(cfg, mesh, batch, cache_len=)`` raises at
  STEP-BUILD time (``ValueError`` naming the config fields) for
  configurations that would otherwise only fail at trace time deep
  inside ``shard_map`` — grouped overlap-bound divisibility at the
  decode token count, hierarchical a2a divisibility
  (``core/moe.validate_dispatch_config``).

Fault seam (``core/faults.py``): the decode callable applies the
host-side ``serve.decode_row`` site to its logits (indexed by the
caller's ``step_index``) — a poisoned grouped decode row, delivered in
the step-builder path so every consumer (``generate``, ``SlotServer``)
sees the same containment surface.  With no ambient plan the jitted
output passes through untouched.
"""
from __future__ import annotations

from collections import Counter
from typing import Any, Callable, Dict, Optional, Tuple

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import faults as faults_mod
from repro.core import moe as moe_lib
from repro.core import tuning
from repro.core.config import DISPATCH_MODES, ModelConfig
from repro.models import transformer as T

# process-wide compiled-step cache: key → callable.  Keys are
# (kind, cfg, mesh, cache_len, batch, long_context); every piece is
# hashable (ModelConfig/MoEConfig are frozen dataclasses, Mesh hashes by
# device assignment).  trace_counts[key] increments INSIDE the traced
# function body, so it counts actual retraces, not calls — the cache-hit
# tests assert it stays put across repeated generate() calls.
_STEP_CACHE: Dict[tuple, Callable] = {}
trace_counts: Counter = Counter()


def clear_step_cache() -> None:
    """Drop every cached compiled step (tests; frees trace caches)."""
    _STEP_CACHE.clear()
    trace_counts.clear()


def trace_budget_report(budget: int = 1, counts=None) -> Dict[tuple, int]:
    """Step-builder keys that traced MORE than ``budget`` times since the
    last ``clear_step_cache`` — the retrace probe behind the
    ``retrace-budget`` lint rule (``repro.analysis``).  Every serving
    shape should trace exactly once per process (the seed re-jitted a
    fresh closure per ``generate()`` call); a key above budget means a
    cache-key leak (an unhashed config field, a fresh mesh per call).
    ``counts`` defaults to the live ``trace_counts`` probe."""
    counts = trace_counts if counts is None else counts
    return {k: int(v) for k, v in counts.items() if int(v) > budget}


def validate_dispatch(dispatch: str) -> str:
    """Validate a serving dispatch-mode name against ``DISPATCH_MODES``
    (shared by ``serve_config`` and the ``launch/serve.py`` CLI flag)."""
    if dispatch not in DISPATCH_MODES:
        raise ValueError(
            f"serving dispatch={dispatch!r} is not a known dispatch "
            f"mode; valid options: {DISPATCH_MODES}")
    return dispatch


def serve_config(cfg: ModelConfig, *, dispatch: Optional[str] = None,
                 payload_dtype: Optional[str] = None) -> ModelConfig:
    """The config actually served: ``dispatch`` / ``payload_dtype``
    (when given) override the MoE knobs — validated, never silently
    dropped.  ``payload_dtype`` quantizes the grouped exchange wire
    (``MoEConfig.payload_dtype``: a ``PAYLOAD_DTYPES`` member or
    ``"auto"``); validation happens in ``MoEConfig.__post_init__`` and
    an ``"auto"`` sentinel resolves at step-BUILD time like every other
    tuned knob, so the resolved wire dtype joins the compiled-step
    cache key for free."""
    if dispatch is None and payload_dtype is None:
        return cfg
    if dispatch is not None:
        validate_dispatch(dispatch)
    if cfg.moe is None:
        knob = ("dispatch" if dispatch is not None else "payload_dtype")
        raise ValueError(
            f"{knob}={dispatch or payload_dtype!r} requested but "
            f"{cfg.name} has no MoE layer (cfg.moe is None) — MoE "
            f"serving overrides only apply to MoE architectures")
    kw = {}
    if dispatch is not None and cfg.moe.dispatch != dispatch:
        kw["dispatch"] = dispatch
    if payload_dtype is not None and cfg.moe.payload_dtype != payload_dtype:
        kw["payload_dtype"] = payload_dtype   # __post_init__ validates
    if not kw:
        return cfg
    return cfg.replace(moe=dataclasses.replace(cfg.moe, **kw))


def _tokens_per_shard(mesh, batch: int) -> int:
    """Static per-shard token count of a decode step: ``batch`` single
    tokens, padded to the device count (``sharded_moe_apply`` pads the
    flattened token axis to the mesh size)."""
    n_dev = 1 if mesh is None else mesh.devices.size
    return (batch + (-batch) % n_dev) // n_dev


def validate_decode_config(cfg: ModelConfig, mesh, batch: int, *,
                           cache_len: Optional[int] = None) -> None:
    """Step-BUILD-time validation of a decode configuration.

    The decode token count is static (``batch`` × 1), so everything the
    grouped path would assert during tracing can be checked here: the
    dispatch/a2a/overlap combination and the overlap-chunk bound
    divisibility at this batch's per-shard token count.  Raises
    ``ValueError`` naming the offending config fields.
    """
    if not cfg.has_decode:
        raise ValueError(f"{cfg.name} is encoder-only — no decode step")
    if batch < 1:
        raise ValueError(f"decode batch must be >= 1, got {batch}")
    if cache_len is not None and cache_len < 2:
        raise ValueError(
            f"cache_len must be >= 2 (one prompt token + one generated), "
            f"got {cache_len}")
    if cfg.moe is None:
        return
    model_size = 1 if mesh is None else int(mesh.shape.get("model", 1))
    moe_lib.validate_dispatch_config(
        cfg.moe, model_size=model_size,
        tokens_per_shard=_tokens_per_shard(mesh, batch),
        d_model=cfg.d_model, dtype=cfg.dtype)


def resolve_decode_config(cfg: ModelConfig, mesh, batch: int) -> ModelConfig:
    """The concrete decode-step config: ``"auto"`` MoE knobs
    (core/tuning.py) resolved at this decode batch's static per-shard
    token count.  ``build_decode`` keys its compiled-step cache on the
    RESULT, so the resolved knobs join the cache key; resolution is
    deterministic and memoized, which keeps repeated builds on one cache
    entry (``trace_counts`` shows no new retraces vs explicit ints).
    Configs without sentinels pass through unchanged."""
    if cfg.moe is None or not tuning.has_auto_knobs(cfg.moe):
        return cfg
    model_size = 1 if mesh is None else int(mesh.shape.get("model", 1))
    moe_cfg = tuning.resolve_moe_config(
        cfg.moe, model_size=model_size,
        tokens_per_shard=_tokens_per_shard(mesh, batch),
        d_model=cfg.d_model, dtype=cfg.dtype)
    return cfg.replace(moe=moe_cfg)


def _cached(key: tuple, make: Callable[[], Callable]) -> Callable:
    fn = _STEP_CACHE.get(key)
    if fn is None:
        fn = make()
        _STEP_CACHE[key] = fn
    return fn


# ---------------------------------------------------------------------------
# raw (uncached, unjitted) step factories — kept for the examples/tests
# that drive the functions eagerly; the builders below wrap these.
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig, mesh=None, *, cache_len: int,
                      long_context: bool = False):
    def prefill(params, tokens):
        B = tokens.shape[0]
        caches = T.init_caches(cfg, B, cache_len, long_context=long_context,
                               dtype=jnp.dtype(cfg.dtype))
        h, _, caches = T.forward(params, tokens, cfg, mesh=mesh,
                                 caches=caches, collect_caches=True,
                                 long_context=long_context)
        logits = T.logits_from_hidden(params, cfg, h[:, -1:], mesh)
        return logits, caches
    return prefill


def make_serve_step(cfg: ModelConfig, mesh=None, *, long_context: bool = False):
    def serve_step(params, token, caches):
        return T.decode_step(params, token, caches, cfg, mesh=mesh,
                             long_context=long_context)
    return serve_step


# ---------------------------------------------------------------------------
# cached step builders
# ---------------------------------------------------------------------------

def build_prefill(cfg: ModelConfig, mesh=None, *, cache_len: int,
                  batch: Optional[int] = None, long_context: bool = False):
    """Cached jitted prefill ``(params, tokens(B,S)) -> (logits, caches)``."""
    key = ("prefill", cfg, mesh, cache_len, batch, long_context)

    def make():
        raw = make_prefill_step(cfg, mesh, cache_len=cache_len,
                                long_context=long_context)

        def prefill(params, tokens):
            trace_counts[key] += 1
            return raw(params, tokens)
        return jax.jit(prefill)
    return _cached(key, make)


def build_decode(cfg: ModelConfig, mesh=None, *, batch: Optional[int] = None,
                 long_context: bool = False):
    """Cached jitted decode step.  Returns a callable
    ``(params, token(B,1), caches, step_index=0) -> (logits, caches)``;
    ``step_index`` feeds the host-side ``serve.decode_row`` fault site
    (one seeded logit element poisoned when the ambient plan fires —
    containment is the scheduler's job, delivery is the builder's).

    ``"auto"`` MoE knobs resolve here, at step-BUILD time, when the
    decode batch is known (:func:`resolve_decode_config`) — the RESOLVED
    config is the cache key.  Prefill builders keep the sentinel config
    as their key (the prompt length is not part of it); their sentinels
    resolve at trace time inside ``sharded_moe_apply`` instead, once per
    jit shape — same determinism, same zero-retrace property."""
    if batch is not None:
        cfg = resolve_decode_config(cfg, mesh, batch)
    key = ("decode", cfg, mesh, None, batch, long_context)

    def make():
        raw = make_serve_step(cfg, mesh, long_context=long_context)

        def step_traced(params, token, caches):
            trace_counts[key] += 1
            return raw(params, token, caches)
        jitted = jax.jit(step_traced)

        def step(params, token, caches, step_index: int = 0):
            logits, new_caches = jitted(params, token, caches)
            if faults_mod.get_active() is not None:
                poisoned = faults_mod.inject_array(
                    "serve.decode_row", logits, index=step_index)
                logits = jnp.asarray(poisoned, dtype=logits.dtype)
            return logits, new_caches
        return step
    return _cached(key, make)


def build_slot_prefill(cfg: ModelConfig, mesh=None, *, cache_len: int,
                       long_context: bool = False):
    """Cached jitted per-slot prefill for ``SlotServer``: run the full
    forward on a ``(1, S)`` prompt against a fresh single-row cache,
    then scatter that cache into row ``slot`` of the batched cache tree
    (``slot`` is static, so each slot index traces once per prompt
    length).  ``(params, prompt, caches, slot) -> (last_logits, caches)``.
    """
    key = ("slot_prefill", cfg, mesh, cache_len, None, long_context)

    def make():
        def slot_prefill(params, prompt, caches, slot):
            trace_counts[key] += 1
            sub = T.init_caches(cfg, 1, cache_len, long_context=long_context,
                                dtype=jnp.dtype(cfg.dtype))
            h, _, sub = T.forward(params, prompt, cfg, mesh=mesh,
                                  caches=sub, collect_caches=True,
                                  long_context=long_context)
            logits = T.logits_from_hidden(params, cfg, h[:, -1:], mesh)

            def put(full, one):
                if one.ndim >= 2 and one.shape[1] == 1:   # (NSB, 1, ...) batch
                    return lax.dynamic_update_slice(
                        full, one.astype(full.dtype),
                        (0, slot) + (0,) * (full.ndim - 2))
                return one.astype(full.dtype)             # scalars (pos)

            return logits[0, -1], jax.tree.map(put, caches, sub)
        return jax.jit(slot_prefill, static_argnums=(3,))
    return _cached(key, make)


# ---------------------------------------------------------------------------
# host-side generation loop
# ---------------------------------------------------------------------------

def generate(params, cfg: ModelConfig, prompt: jax.Array, *, steps: int,
             mesh=None, cache_len: Optional[int] = None,
             temperature: float = 0.0, rng: Optional[jax.Array] = None,
             long_context: bool = False,
             dispatch: Optional[str] = None,
             payload_dtype: Optional[str] = None) -> jax.Array:
    """Greedy/temperature generation.  prompt (B, S) → (B, S+steps).

    ``dispatch`` overrides the MoE dispatch mode for serving (validated
    against ``DISPATCH_MODES``); ``payload_dtype`` quantizes the
    grouped exchange wire (see :func:`serve_config`).  Steps come from
    the compiled-step cache: repeated calls with identical shapes never
    retrace.
    """
    assert cfg.has_decode, f"{cfg.name} is encoder-only"
    cfg = serve_config(cfg, dispatch=dispatch, payload_dtype=payload_dtype)
    B, S = prompt.shape[:2]
    cache_len = cache_len or (S + steps)
    validate_decode_config(cfg, mesh, B, cache_len=cache_len)
    prefill = build_prefill(cfg, mesh, cache_len=cache_len, batch=B,
                            long_context=long_context)
    step = build_decode(cfg, mesh, batch=B, long_context=long_context)
    logits, caches = prefill(params, prompt)
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    out = [prompt]
    tok = None
    for i in range(steps):
        if temperature > 0:
            rng, k = jax.random.split(rng)
            tok = jax.random.categorical(k, logits[:, -1] / temperature)[:, None]
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        out.append(tok)
        if i + 1 < steps:
            logits, caches = step(params, tok, caches, step_index=i)
    return jnp.concatenate(out, axis=1)
