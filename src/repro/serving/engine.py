"""Serving: prefill + batched single-token decode steps.

``serve_step`` is what the decode dry-run shapes lower: ONE new token per
sequence against a KV/state cache of ``seq_len`` (decode_32k) or the
bounded ring/recurrent state (long_500k).  ``generate`` is the host-side
loop used by the examples and integration tests (greedy or temperature
sampling).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.config import ModelConfig
from repro.models import transformer as T


def make_prefill_step(cfg: ModelConfig, mesh=None, *, cache_len: int,
                      long_context: bool = False):
    def prefill(params, tokens):
        B = tokens.shape[0]
        caches = T.init_caches(cfg, B, cache_len, long_context=long_context,
                               dtype=jnp.dtype(cfg.dtype))
        h, _, caches = T.forward(params, tokens, cfg, mesh=mesh,
                                 caches=caches, collect_caches=True,
                                 long_context=long_context)
        logits = T.logits_from_hidden(params, cfg, h[:, -1:], mesh)
        return logits, caches
    return prefill


def make_serve_step(cfg: ModelConfig, mesh=None, *, long_context: bool = False):
    def serve_step(params, token, caches):
        return T.decode_step(params, token, caches, cfg, mesh=mesh,
                             long_context=long_context)
    return serve_step


def generate(params, cfg: ModelConfig, prompt: jax.Array, *, steps: int,
             mesh=None, cache_len: Optional[int] = None,
             temperature: float = 0.0, rng: Optional[jax.Array] = None,
             long_context: bool = False) -> jax.Array:
    """Greedy/temperature generation.  prompt (B, S) → (B, S+steps)."""
    assert cfg.has_decode, f"{cfg.name} is encoder-only"
    B, S = prompt.shape[:2]
    cache_len = cache_len or (S + steps)
    prefill = jax.jit(make_prefill_step(cfg, mesh, cache_len=cache_len,
                                        long_context=long_context))
    step = jax.jit(make_serve_step(cfg, mesh, long_context=long_context))
    logits, caches = prefill(params, prompt)
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    out = [prompt]
    tok = None
    for i in range(steps):
        if temperature > 0:
            rng, k = jax.random.split(rng)
            tok = jax.random.categorical(k, logits[:, -1] / temperature)[:, None]
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        out.append(tok)
        if i + 1 < steps:
            logits, caches = step(params, tok, caches)
    return jnp.concatenate(out, axis=1)
