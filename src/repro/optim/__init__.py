from repro.optim.adamw import (adamw_update, clip_by_global_norm,
                               init_opt_state, make_schedule)
