"""AdamW + LR schedules + global-norm clipping (pure JAX, no optax).

Moments can be stored bf16 (``TrainConfig.optimizer_state_dtype``) — the
memory knob for the giant configs (llama4-400b master+moments dominate
per-chip HBM; see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import TrainConfig


def init_opt_state(params, cfg: TrainConfig) -> Dict:
    dt = jnp.dtype(cfg.optimizer_state_dtype)
    zeros = lambda p: jnp.zeros_like(p, dtype=dt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gn


def adamw_update(grads, state: Dict, params, cfg: TrainConfig,
                 lr: jax.Array) -> Tuple[Dict, Dict]:
    """Returns (new_params, new_state).  Decoupled weight decay."""
    c = state["count"] + 1
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** c.astype(jnp.float32)
    bc2 = 1.0 - b2 ** c.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        step = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (step + cfg.weight_decay * p32)
        return p32.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    # flatten (param trees may contain tuples — can't use tuple-is_leaf tricks)
    p_leaves, treedef = jax.tree.flatten(params)
    g_leaves = treedef.flatten_up_to(grads)
    m_leaves = treedef.flatten_up_to(state["m"])
    v_leaves = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(p_leaves, g_leaves, m_leaves, v_leaves)]
    unf = lambda i: jax.tree.unflatten(treedef, [o[i] for o in out])
    return unf(0), {"m": unf(1), "v": unf(2), "count": c}


def make_schedule(cfg: TrainConfig) -> Callable[[jax.Array], jax.Array]:
    def sched(step):
        s = step.astype(jnp.float32)
        warm = cfg.learning_rate * s / max(cfg.warmup_steps, 1)
        if cfg.schedule == "cosine":
            t = jnp.clip((s - cfg.warmup_steps)
                         / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
            rest = cfg.learning_rate * 0.5 * (1 + jnp.cos(jnp.pi * t))
        else:
            t = jnp.clip((s - cfg.warmup_steps)
                         / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
            rest = cfg.learning_rate * (1 - t)
        return jnp.where(s < cfg.warmup_steps, warm, rest)
    return sched
