"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from the
cached experiments/dryrun/*.json records.

  python experiments/make_tables.py [--mesh 16x16] [--tag '']
"""
import argparse
import glob
import json
import os

HERE = os.path.dirname(os.path.abspath(__file__))

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = ["rwkv6-1.6b", "h2o-danube-3-4b", "yi-6b",
              "llama4-maverick-400b-a17b", "dbrx-132b", "internvl2-2b",
              "zamba2-7b", "gemma2-9b", "hubert-xlarge", "starcoder2-3b"]


def load(mesh: str, tag: str = ""):
    recs = {}
    for f in glob.glob(os.path.join(HERE, "dryrun", "*.json")):
        base = os.path.basename(f)[:-5]
        parts = base.split("__")
        want = 4 if tag else 3
        if len(parts) != want or parts[2] != mesh:
            continue
        if tag and parts[3] != tag:
            continue
        with open(f) as fh:
            recs[(parts[0], parts[1])] = json.load(fh)
    return recs


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def table(mesh: str, tag: str = ""):
    recs = load(mesh, tag)
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "mem/dev GiB | HLO TFLOP/dev | coll GB/dev | useful frac |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    n_run = n_skip = 0
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s))
            if r is None:
                lines.append(f"| {a} | {s} | — | — | — | MISSING | | | | |")
                continue
            if "skipped" in r:
                n_skip += 1
                lines.append(f"| {a} | {s} | — | — | — | *skip: "
                             f"{r['skipped']}* | | | | |")
                continue
            n_run += 1
            ro = r["roofline"]
            ma = r["memory_analysis"]
            ca = r["hlo_analysis"]
            co = r["collectives"]
            lines.append(
                f"| {a} | {s} | {ro['compute_s']:.2e} | {ro['memory_s']:.2e} "
                f"| {ro['collective_s']:.2e} | **{ro['dominant'].replace('_s','')}** "
                f"| {fmt_bytes(ma['peak_per_device_bytes'])} "
                f"| {ca.get('flops', 0)/1e12:.2f} "
                f"| {co['total_wire_bytes']/1e9:.2f} "
                f"| {min(ro['useful_fraction'], 9.99):.2f} |")
    lines.append(f"\n{n_run} pairs lowered+compiled, {n_skip} documented skips "
                 f"(mesh {mesh}).")
    return "\n".join(lines)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    print(table(args.mesh, args.tag))


def compare(mesh: str = "16x16", tag: str = "baseline"):
    """Baseline vs optimized step-time bound per pair."""
    base = load(mesh, tag)
    opt = load(mesh)
    lines = ["| arch | shape | baseline bound s (dom) | optimized bound s (dom) | speedup |",
             "|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            b, o = base.get((a, s)), opt.get((a, s))
            if not b or not o or "skipped" in b or "skipped" in o:
                continue
            rb, ro = b["roofline"], o["roofline"]
            sp = rb["step_time_bound_s"] / max(ro["step_time_bound_s"], 1e-12)
            mark = " **HILLCLIMBED**" if (a, s) in (
                ("dbrx-132b", "train_4k"),
                ("llama4-maverick-400b-a17b", "decode_32k"),
                ("zamba2-7b", "train_4k")) else ""
            lines.append(
                f"| {a} | {s} | {rb['step_time_bound_s']:.2e} "
                f"({rb['dominant'].replace('_s','')}) | "
                f"{ro['step_time_bound_s']:.2e} "
                f"({ro['dominant'].replace('_s','')}) | "
                f"{sp:.2f}×{mark} |")
    return "\n".join(lines)
