"""Workload-replay serving benchmarks (suite ``traffic``).

Replays seeded traffic scenarios (``repro/serving/traffic.py``) against
a grouped-dispatch ``SlotServer`` and emits the serving SLO numbers —
p50 per-token latency as the gated µs, with p99, time-to-first-token
and slot utilization as recorded ratios:

* ``serve/traffic/poisson`` — Poisson arrivals, mixed prompt/output
  lengths (steady-state continuous batching);
* ``serve/traffic/bursty``  — synchronized bursts bigger than the slot
  pool (queueing + aligned-refill pressure);
* ``serve/traffic/skewed``  — the bursty workload with every MoE router
  adversarially biased toward one expert (``skew_router``): the
  hot-expert regime where capacity padding drops tokens and dropless
  grouped compute must absorb the whole load on one segment.

The workload (arrival steps, prompt/output lengths, statuses) is
deterministic per seed; only the wall-clock latencies move with the
machine, which is exactly what ``run.py --check``'s drift
normalization expects.
"""
import jax

from benchmarks.common import emit
from repro import configs
from repro.launch.mesh import make_smoke_mesh
from repro.models import transformer as T
from repro.serving import SlotServer
from repro.serving.traffic import TrafficConfig, replay, skew_router, \
    synthesize_workload

SLOTS = 4
CACHE_LEN = 24


def run(paper: bool = False):
    cfg = configs.smoke_config("hetumoe-paper-16e")
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    mesh = make_smoke_mesh((1, 1))
    n = 24 if paper else 12
    scenarios = (
        ("poisson", TrafficConfig(num_requests=n, arrival="poisson",
                                  rate=0.4, seed=7), params),
        ("bursty", TrafficConfig(num_requests=n, arrival="bursty",
                                 burst_size=6, burst_every=8, seed=11),
         params),
        ("skewed", TrafficConfig(num_requests=n, arrival="bursty",
                                 burst_size=6, burst_every=8, seed=11),
         skew_router(params)),
    )
    for name, tc, p in scenarios:
        srv = SlotServer(cfg, p, slots=SLOTS, cache_len=CACHE_LEN, mesh=mesh,
                         dispatch="grouped", queue_limit=4 * SLOTS)
        rep = replay(srv, synthesize_workload(tc, cfg))
        emit(f"serve/traffic/{name}", rep.p50_per_token_s * 1e6,
             rep.summary(),
             p99_per_token_us=rep.p99_per_token_s * 1e6,
             p50_first_token_us=rep.p50_first_token_s * 1e6,
             slot_utilization=rep.slot_utilization,
             completed=rep.completed,
             tokens_out=rep.tokens_out)
