"""Paper Fig. 7 — hierarchical vs flat AllToAll.

Two views:
  (a) α–β cost model in the PAPER's regime (N nodes × 8 GPUs, PCIe +
      one 100 Gb NIC) — reproduces the claimed 1.66×(4×8) / 2×(8×8)
      speedups from message aggregation.
  (b) TPU-adapted regime: the same two-stage factoring across a v5e
      mesh axis with an ICI fast dim and a DCN-grade slow dim.
  (c) functional wall time on 8 fake CPU devices (structure only).
"""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from benchmarks.common import emit, timeit
from repro.core import alltoall
from repro.core.compat import shard_map
from repro.core.alltoall import cost_flat, cost_hierarchical
from repro.launch.mesh import parse_fabric


def run(paper: bool = False):
    B = 16e6                                      # paper: ~16 MB per GPU
    _, (pcie, eth100) = parse_fabric("pcie_eth100")
    _, (ici, dcn) = parse_fabric("ici_dcn")
    for N, G in [(2, 8), (4, 8), (8, 8), (16, 8)]:
        f = cost_flat(B, N, G, pcie, eth100)
        h = cost_hierarchical(B, N, G, pcie, eth100)
        emit(f"a2a/model/gpu-{N}x{G}", h * 1e6,
             f"flat_us={f * 1e6:.0f},speedup={f / h:.2f}x"
             f"{',paper_claims=1.66x' if N == 4 else ''}"
             f"{',paper_claims=2x' if N == 8 else ''}")
    # TPU adaptation: slow dim = DCN (pod boundary), fast dim = ICI
    for N, G in [(2, 16), (4, 16)]:
        f = cost_flat(B, N, G, ici, dcn)
        h = cost_hierarchical(B, N, G, ici, dcn)
        emit(f"a2a/model/tpu-{N}pods-x{G}", h * 1e6,
             f"flat_us={f * 1e6:.0f},speedup={f / h:.2f}x")

    # functional path on 8 fake devices
    if len(jax.devices()) >= 8:
        import numpy as np
        mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:8]).reshape(8),
                                 ("model",))
        x = jax.random.normal(jax.random.PRNGKey(0), (64, 64, 128))
        flat = jax.jit(shard_map(
            lambda v: alltoall.flat_all_to_all(v, "model"), mesh=mesh,
            in_specs=P("model"), out_specs=P("model"), check_vma=False))
        hier = jax.jit(shard_map(
            lambda v: alltoall.hierarchical_all_to_all(v, "model", inner=4,
                                                       outer=2),
            mesh=mesh, in_specs=P("model"), out_specs=P("model"),
            check_vma=False))
        emit("a2a/functional/flat-8dev", timeit(flat, x), "")
        emit("a2a/functional/hier-8dev", timeit(hier, x),
             "cpu-emulated; see alpha-beta model for fabric-level numbers")


if __name__ == "__main__":
    run()
