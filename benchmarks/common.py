"""Shared benchmark utilities.

CPU NOTE: this container benchmarks on 1 CPU core (+ interpret-mode
Pallas), so absolute times are NOT TPU numbers.  What transfers:
relative comparisons between algorithmic variants (sort vs dense
dispatch, iterative-max vs sort top-k) and the α–β model numbers.
Dims default to a reduced profile; ``--paper`` uses the paper's exact
16e / d=2048 / seq=1024 layer.
"""
import time

import jax

# Every emit() also lands here so run.py can write the machine-readable
# BENCH_moe.json (name → µs + numeric ratios) for cross-PR perf tracking.
RESULTS = []


def timeit(fn, *args, warmup: int = 2, iters: int = 7) -> float:
    """MIN wall time per call in microseconds (jit + block_until_ready).

    Min, not median: on this throttled shared-CPU container the upper
    quantiles are dominated by scheduler preemption, which made the
    committed BENCH_moe.json numbers flap by >25% run-to-run and trip
    ``run.py --check`` on pure noise.  The fastest observed iteration is
    the standard low-variance estimator of what the code CAN do.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return min(times) * 1e6


def emit(name: str, us: float, derived: str = "", **ratios: float):
    """Print one CSV line and record it; keyword args are numeric ratios
    (e.g. ``speedup_vs_dense=2.1``) preserved as JSON fields."""
    print(f"{name},{us:.1f},{derived}")
    RESULTS.append({"name": name, "us": us, "derived": derived,
                    "ratios": {k: float(v) for k, v in ratios.items()}})
