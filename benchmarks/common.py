"""Shared benchmark utilities.

CPU NOTE: this container benchmarks on 1 CPU core (+ interpret-mode
Pallas), so absolute times are NOT TPU numbers.  What transfers:
relative comparisons between algorithmic variants (sort vs dense
dispatch, iterative-max vs sort top-k) and the α–β model numbers.
Dims default to a reduced profile; ``--paper`` uses the paper's exact
16e / d=2048 / seq=1024 layer.
"""
import time

import jax

# Every emit() also lands here so run.py can write the machine-readable
# BENCH_moe.json (name → µs + numeric ratios) for cross-PR perf tracking.
RESULTS = []


def timeit(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time per call in microseconds (jit + block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us: float, derived: str = "", **ratios: float):
    """Print one CSV line and record it; keyword args are numeric ratios
    (e.g. ``speedup_vs_dense=2.1``) preserved as JSON fields."""
    print(f"{name},{us:.1f},{derived}")
    RESULTS.append({"name": name, "us": us, "derived": derived,
                    "ratios": {k: float(v) for k, v in ratios.items()}})
