"""Shared benchmark utilities.

CPU NOTE: this container benchmarks on 1 CPU core (+ interpret-mode
Pallas), so absolute times are NOT TPU numbers.  What transfers:
relative comparisons between algorithmic variants (sort vs dense
dispatch, iterative-max vs sort top-k) and the α–β model numbers.
Dims default to a reduced profile; ``--paper`` uses the paper's exact
16e / d=2048 / seq=1024 layer.
"""
import time

import jax


def timeit(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time per call in microseconds (jit + block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}")
