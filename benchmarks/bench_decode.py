"""Decode/serving microbenchmarks (the maxtext decode-microbenchmark
pattern applied to the MoE serving path).

Entries (suite ``decode``):

* ``decode/prefill/len{L}`` — prefill latency by prompt length under the
  grouped serving config (prefill tokens/s derived);
* ``decode/step/{sort,grouped}`` — ONE batched single-token decode step,
  capacity-padded vs dropless grouped, on the single-device mesh —
  decode batches are tiny and latency-bound, exactly where capacity
  padding hurts (``grouped_vs_sort`` ratio on the grouped entry);
* ``decode/step/ep/{sort,grouped}`` — the same step on the
  (data=2, model=4) serving mesh: grouped-EP AllToAll × expert-TP
  against the capacity-padded exchange;
* ``decode/step/ep/grouped_int8`` — that grouped-EP step over the
  int8 exchange wire (PR 10; ``int8_vs_bf16`` bounds the quant/dequant
  overhead on this CPU container);
* ``decode/ar/grouped`` — a {GEN}-step autoregressive loop: AR
  tokens/sec and per-device GB/s (params + cache traffic per step —
  the decode roofline quantity).

All steps come from the ``serving/engine.py`` step-builder cache, so
this suite also exercises the no-retrace serving contract.  CPU note:
absolute µs are CPU-emulation numbers; the sort-vs-grouped ratios and
the tokens/s / GB/s derivations are the tracked deliverables
(``run.py --check`` gates them like every other suite).
"""
import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro import configs
from repro.launch.mesh import make_smoke_mesh
from repro.models import transformer as T
from repro.serving import engine

BATCH = 8
GEN = 16


def _model(paper: bool):
    cfg = (configs.get_config if paper
           else configs.smoke_config)("hetumoe-paper-16e")
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _bytes(tree) -> int:
    return sum(x.nbytes for x in jax.tree.leaves(tree))


def run(paper: bool = False):
    cfg, params = _model(paper)
    mesh = make_smoke_mesh((1, 1))
    lens = (128, 256, 512) if paper else (16, 32, 64)
    cache_len = max(lens) + GEN
    rng = jax.random.PRNGKey(1)
    gcfg = engine.serve_config(cfg, dispatch="grouped")

    # -- prefill by prompt length (grouped serving config) ------------------
    for L in lens:
        prompt = jax.random.randint(rng, (BATCH, L), 0, cfg.vocab_size)
        prefill = engine.build_prefill(gcfg, mesh, cache_len=cache_len,
                                       batch=BATCH)
        us = timeit(prefill, params, prompt)
        emit(f"decode/prefill/len{L}", us,
             f"prefill {BATCH * L / us * 1e6:.0f} tok/s",
             prefill_tokens_per_s=BATCH * L / us * 1e6)

    # -- one decode step: sort vs grouped -----------------------------------
    def step_entry(name, scfg, step_mesh, ratio_vs=None,
                   ratio_key="grouped_vs_sort"):
        prefill = engine.build_prefill(scfg, step_mesh, cache_len=cache_len,
                                       batch=BATCH)
        prompt = jax.random.randint(rng, (BATCH, lens[0]), 0, cfg.vocab_size)
        logits, caches = prefill(params, prompt)
        tok = jnp.argmax(logits[:, -1], -1)[:, None]
        step = engine.build_decode(scfg, step_mesh, batch=BATCH)
        us = timeit(step, params, tok, caches)
        n_dev = step_mesh.devices.size
        gbps = (_bytes(params) + _bytes(caches)) / (us * 1e-6) / 1e9 / n_dev
        ratios = dict(tokens_per_s=BATCH / us * 1e6, gbps_per_device=gbps)
        if ratio_vs:
            ratios[ratio_key] = ratio_vs / us
        emit(name, us, f"{BATCH / us * 1e6:.0f} tok/s, "
             f"{gbps:.2f} GB/s/dev", **ratios)
        return us, tok, caches, step

    sort_us, *_ = step_entry("decode/step/sort",
                             engine.serve_config(cfg, dispatch="sort"), mesh)
    _, tok, caches, gstep = step_entry("decode/step/grouped", gcfg, mesh,
                                       ratio_vs=sort_us)

    # -- the same step on the (data=2, model=4) serving mesh ----------------
    mesh_ep = make_smoke_mesh((2, 4))
    ep_sort_us, *_ = step_entry("decode/step/ep/sort",
                                engine.serve_config(cfg, dispatch="sort"),
                                mesh_ep)
    ep_grouped_us, *_ = step_entry("decode/step/ep/grouped", gcfg, mesh_ep,
                                   ratio_vs=ep_sort_us)
    # PR 10: the same EP step over the int8 exchange wire — decode steps
    # are latency-bound, exactly where the α–β model says the 1-byte
    # payload pays; on CPU the ratio bounds the quant/dequant overhead
    step_entry("decode/step/ep/grouped_int8",
               engine.serve_config(cfg, dispatch="grouped",
                                   payload_dtype="int8"),
               mesh_ep, ratio_vs=ep_grouped_us, ratio_key="int8_vs_bf16")

    # -- autoregressive loop: tokens/sec + per-device GB/s ------------------
    def ar(params, tok, caches):
        for i in range(GEN):
            logits, caches = gstep(params, tok, caches, step_index=i)
            tok = jnp.argmax(logits[:, -1], -1)[:, None]
        return tok

    us = timeit(ar, params, tok, caches)
    tps = BATCH * GEN / us * 1e6
    gbps = GEN * (_bytes(params) + _bytes(caches)) / (us * 1e-6) / 1e9
    emit("decode/ar/grouped", us, f"AR {tps:.0f} tok/s, {gbps:.2f} GB/s/dev",
         ar_tokens_per_s=tps, gbps_per_device=gbps)
