"""Auto-tuned dispatch plans vs hand-set knobs (core/tuning.py).

Each cell times the full MoE layer twice on the same mesh: once with
the hand-set grouped knobs the presets used to ship (``a2a="flat"``,
``overlap_chunks=1``, kernel-default block_m) and once with every
grouped knob set to ``"auto"`` so ``tuning.resolve_plan`` picks them
from the α–β cost model.  Both the MEASURED auto-vs-hand ratio and the
cost model's PREDICTED ratio for the same cell are emitted side by
side — on this CPU container collectives are emulated, so the measured
number bounds the resolver's overhead (it must be ~1.0×: resolution
happens once per trace, never per step) while the predicted column is
the fabric-level deliverable the tuner actually optimizes.

Cells: grouped-EP (4-way model mesh), grouped-TP ((2,4) data×model
mesh), the overlap-pipeline cell (hand-set P=2 vs the resolved P), and
the payload cell (PR 10: full-width bf16 wire vs
``payload_dtype="auto"`` — predicted α–β saving of the resolved wire
vs the measured ratio) — the same meshes as the
``grouped``/``grouped_overlap`` suites, so the numbers are directly
comparable.  Tracked under ``run.py --check`` like every grouped suite.
"""
import dataclasses

import jax.numpy as jnp

from benchmarks.bench_grouped import EP_WAYS, TP_MESH, _sharded_setup
from benchmarks.common import emit, timeit
from repro.core import tuning
from repro.core.config import MoEConfig


def _auto(cfg: MoEConfig) -> MoEConfig:
    return dataclasses.replace(
        cfg, a2a="auto", overlap_chunks="auto", grouped_block_m="auto",
        grouped_ep_bound_factor="auto")


def _cell(key_tag: str, hand: MoEConfig, *, model_size: int,
          tokens_per_shard: int, d_model: int, paper: bool,
          mesh_shape, mesh_axes, tp_axis) -> None:
    setup = _sharded_setup(mesh_shape, mesh_axes, tp_axis,
                           f"tuning-{key_tag}", paper)
    if setup is None:
        return
    layer_fn, params, x, E, S = setup
    auto = _auto(hand)
    plan = tuning.resolve_plan(auto, model_size=model_size,
                               tokens_per_shard=tokens_per_shard,
                               d_model=d_model, dtype=x.dtype)
    t_hand = timeit(layer_fn(hand), params, x)
    t_auto = timeit(layer_fn(auto), params, x)
    pred_a2a = (plan.cost_flat / plan.cost_chosen
                if plan.cost_chosen else 1.0)
    pred_overlap = (plan.cost_serial / plan.cost_overlapped
                    if plan.cost_overlapped else 1.0)
    emit(f"tuning/{key_tag}/hand/S{S}", t_hand,
         f"a2a={hand.a2a} P={hand.overlap_chunks}")
    emit(f"tuning/{key_tag}/auto/S{S}", t_auto,
         f"resolved a2a={plan.a2a} inner={plan.a2a_inner} "
         f"P={plan.overlap_chunks} block_m={plan.grouped_block_m}; "
         f"measured vs_hand={t_hand / t_auto:.2f}x; "
         f"predicted a2a={pred_a2a:.2f}x overlap={pred_overlap:.2f}x "
         f"({plan.fabric}, {plan.payload_bytes / 1e3:.0f}KB)",
         vs_hand=t_hand / t_auto,
         predicted_a2a=pred_a2a,
         predicted_overlap=pred_overlap)


def _payload_cell(hand: MoEConfig, *, paper: bool) -> None:
    """PR 10 predicted-vs-measured payload cell: the bf16 grouped-EP
    layer with the hand-set full-width wire vs ``payload_dtype="auto"``
    (everything else identical), plus the α–β model's predicted flat-a2a
    speedup of the resolved wire for the same cell.

    The cell is deliberately β-DOMINATED — 4× the tokens and 2× the
    width of the other tuning cells — because at the shared smoke dims
    the per-hop latency dominates and the auto policy (correctly) stays
    lossless (``QUANT_MIN_SAVING``); the whole point of this cell is to
    watch the resolver flip to int8 where the payload is the cost."""
    import jax

    if len(jax.devices()) < EP_WAYS:
        print(f"# WARNING: tuning/payload SKIPPED — "
              f"{len(jax.devices())} device(s) < {EP_WAYS}")
        return
    from repro.core import moe
    from repro.launch.mesh import make_smoke_mesh
    mesh = make_smoke_mesh((EP_WAYS,), ("model",))
    d, d_ff, E = (1024, 512, 16) if paper else (256, 128, 16)
    S = 4096 if paper else 2048
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (S, d), jnp.bfloat16)
    params = moe.init_moe_params(key, hand, d, d_ff, E, act="relu",
                                 dtype=jnp.bfloat16)

    def layer_fn(cfg):
        @jax.jit
        def fn(p, v):
            y, _, _ = moe.sharded_moe_apply(mesh, cfg, p, v,
                                            num_experts=E, act="relu")
            return y
        return fn

    auto = dataclasses.replace(hand, payload_dtype="auto")
    resolve = lambda c: tuning.resolve_plan(
        c, model_size=EP_WAYS, tokens_per_shard=S // EP_WAYS,
        d_model=d, dtype=x.dtype)
    full, plan = resolve(hand), resolve(auto)
    t_hand = timeit(layer_fn(hand), params, x)
    t_auto = timeit(layer_fn(auto), params, x)
    pred = full.cost_flat / plan.cost_flat if plan.cost_flat else 1.0
    emit(f"tuning/payload/hand/S{S}", t_hand,
         f"full-width bf16 wire ({full.payload_bytes / 1e3:.0f}KB)")
    emit(f"tuning/payload/auto/S{S}", t_auto,
         f"resolved payload_dtype={plan.payload_dtype!r} "
         f"({plan.payload_bytes / 1e3:.0f}KB); measured "
         f"vs_hand={t_hand / t_auto:.2f}x; predicted "
         f"a2a={pred:.2f}x ({plan.fabric})",
         vs_hand=t_hand / t_auto, predicted_payload_a2a=pred)


def run(paper: bool = False):
    prev = tuning.set_tuning(mode="auto", fabric="ici_dcn")
    try:
        d = 512 if paper else 128
        S = 2048 if paper else 512
        grouped = MoEConfig(num_experts=16, gate="switch",
                            capacity_factor=1.25, dispatch="grouped",
                            a2a="flat", overlap_chunks=1)
        # EP: 4-way model mesh — tokens_per_shard matches
        # sharded_moe_apply's S // n_dev at trace time
        _cell("ep4", grouped, model_size=EP_WAYS,
              tokens_per_shard=S // EP_WAYS, d_model=d, paper=paper,
              mesh_shape=(EP_WAYS,), mesh_axes=("model",), tp_axis=None)
        # TP×EP: (data=2, model=4) mesh, expert f dim over data
        n_tp = TP_MESH[0] * TP_MESH[1]
        _cell("tp", grouped, model_size=TP_MESH[1],
              tokens_per_shard=S // n_tp, d_model=d, paper=paper,
              mesh_shape=TP_MESH, mesh_axes=("data", "model"),
              tp_axis="data")
        # overlap: hand-set P=2 (the grouped_overlap suite's middle
        # point) vs whatever P the resolver picks for this cell
        overlap2 = dataclasses.replace(grouped, overlap_chunks=2)
        _cell("overlap", overlap2, model_size=EP_WAYS,
              tokens_per_shard=S // EP_WAYS, d_model=d, paper=paper,
              mesh_shape=(EP_WAYS,), mesh_axes=("model",), tp_axis=None)
        # payload: hand-set full-width wire vs ``payload_dtype="auto"``
        # (PR 10) — the predicted α–β saving of the resolved wire next
        # to the measured ratio (on CPU the latter bounds the
        # quant/dequant overhead, ~1.0×)
        _payload_cell(grouped, paper=paper)
    finally:
        tuning.set_tuning(mode=prev[0], fabric=prev[1])


if __name__ == "__main__":
    run()
