"""Paper Fig. 8 — overall MoE layer performance vs batch size, HetuMoE
(sort dispatch + fused gating path) vs the DeepSpeed-style baseline
(dense one-hot einsum dispatch), under switch and gshard gates.

Paper: ≥15% over Tutel/FastMoE, up to 8.1× over DeepSpeed-MoE (switch,
bs=32).  The DeepSpeed gap is dominated by the dense-dispatch einsum,
which this bench isolates.  8 fake devices so the AllToAll is in the
measured path.
"""
import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import moe
from repro.core.config import MoEConfig


def run(paper: bool = False):
    d, d_ff, E = (2048, 2048, 16) if paper else (512, 512, 16)
    seq = 1024 if paper else 256
    batches = [8, 16, 32] if paper else [1, 2, 4]
    n_dev = min(len(jax.devices()), 8)
    mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:n_dev]).reshape(1, n_dev),
                             ("data", "model"))
    key = jax.random.PRNGKey(0)
    for gate in ("switch", "gshard"):
        for bs in batches:
            x = jax.random.normal(key, (bs, seq, d), jnp.float32)
            res = {}
            for name, dispatch in (("hetumoe", "sort"), ("deepspeed-style", "dense")):
                cfg = MoEConfig(num_experts=E, gate=gate, dispatch=dispatch,
                                capacity_factor=1.25)
                params = moe.init_moe_params(key, cfg, d, d_ff, E, act="relu",
                                             dtype=jnp.float32)
                fn = jax.jit(lambda p, v, cfg=cfg: moe.sharded_moe_apply(
                    mesh, cfg, p, v, num_experts=E, act="relu")[0])
                res[name] = timeit(fn, params, x, warmup=2, iters=3)
            sp = res["deepspeed-style"] / res["hetumoe"]
            emit(f"overall/hetumoe/{gate}/bs{bs}", res["hetumoe"],
                 f"speedup_vs_dense={sp:.2f}x")
            emit(f"overall/deepspeed-style/{gate}/bs{bs}",
                 res["deepspeed-style"], "")


if __name__ == "__main__":
    run()
