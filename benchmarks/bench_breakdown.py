"""Paper Fig. 1 — MoE layer time breakdown (gate / layout / AllToAll /
expert FFN).

The paper profiles DeepSpeed-MoE on 8×A100 and finds gate+layout+a2a eat
>50% of the layer.  We decompose OUR layer the same way on the paper's
16e / d=2048 config (reduced dims off --paper) and report component
shares for both dispatch modes.
"""
import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import capacity, gating, layout, moe
from repro.core.config import MoEConfig


def run(paper: bool = False):
    d, d_ff, E = (2048, 2048, 16) if paper else (512, 512, 16)
    S = 4096 if paper else 1024
    cfg = MoEConfig(num_experts=E, gate="switch", capacity_factor=1.25)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (S, d), jnp.float32)
    params = moe.init_moe_params(key, cfg, d, d_ff, E, act="relu",
                                 dtype=jnp.float32)
    C = capacity.expert_capacity(cfg, S, E)

    gate_fn = jax.jit(lambda x: gating.route(
        cfg, gating.router_logits(cfg, x, params["gate_w"])).expert_index)

    @jax.jit
    def layout_fn(x):
        g = gating.route(cfg, gating.router_logits(cfg, x, params["gate_w"]))
        plan = layout.plan_sort(g, E, C)
        buf = layout.dispatch_scatter(x, plan, E, C)
        return layout.combine_gather(buf, plan)

    buf0 = jax.random.normal(key, (E, C, d), jnp.float32)

    @jax.jit
    def expert_fn(buf):
        return moe.expert_ffn(params, buf, "relu")

    @jax.jit
    def full_fn(x):
        y, aux, _ = moe.moe_block_local(cfg, params, x, num_experts=E,
                                        act="relu")
        return y

    cfg_g = MoEConfig(num_experts=E, gate="switch", capacity_factor=1.25,
                      dispatch="grouped")

    @jax.jit
    def full_grouped_fn(x):
        y, aux, _ = moe.moe_block_local(cfg_g, params, x, num_experts=E,
                                        act="relu")
        return y

    t_gate = timeit(gate_fn, x)
    t_layout = max(timeit(layout_fn, x) - t_gate, 0.0)
    t_expert = timeit(expert_fn, buf0)
    t_full = timeit(full_fn, x)
    t_grouped = timeit(full_grouped_fn, x)
    tot = max(t_full, 1e-9)
    emit(f"breakdown/gate/S{S}", t_gate, f"share={t_gate / tot:.1%}")
    emit(f"breakdown/layout/S{S}", t_layout, f"share={t_layout / tot:.1%}")
    emit(f"breakdown/expert/S{S}", t_expert, f"share={t_expert / tot:.1%}")
    emit(f"breakdown/full-layer/S{S}", t_full,
         "a2a excluded on 1 device; fig7 model covers it")
    emit(f"breakdown/full-layer-grouped/S{S}", t_grouped,
         f"dropless; sort_vs_grouped={t_full / t_grouped:.2f}x",
         sort_vs_grouped=t_full / t_grouped)


if __name__ == "__main__":
    run()
