"""Dropless grouped dispatch vs the capacity-padded paths, swept over
capacity factor (MegaBlocks Fig. 5 analogue; HetuMoE has no dropless
mode — this is our extension).

The padded (E·C, d) buffer wastes FLOPs at LOW capacity factor (the
buffer is mostly empty under imbalance) and drops tokens at HIGH load;
the grouped path computes exactly Σ_e n_e FFN rows at every cf and
never drops.  Each cf line reports sort/dense/grouped full-layer times,
the grouped-vs-padded ratios, and the sort path's drop rate — the
quality cost the padded modes pay that grouped doesn't.

CPU note: XLA-CPU lowers ``ragged_dot`` as a serial loop (≈9× the
equivalent dense einsum here), so grouped ABSOLUTE µs are pessimistic
in this container; on TPU the ragged matmul is MXU-native and the
grouped FLOP count (Σ n_e rows, no padding) is the lower bound.  The
drop-rate column is the load-independent deliverable.

``run_ep`` adds the expert-parallel configuration: the grouped
AllToAll (count exchange + bounded segments) vs the capacity-padded
sort exchange on a 4-way model mesh, flat and hierarchical — the
composition of the paper's two-stage a2a with dropless dispatch.

``run_tp`` (the ``grouped/tp/*`` entries) adds expert TENSOR
parallelism on top: a (data=2, model=4) mesh with the expert weights'
f dim sharded over ``data`` — the ragged-aware TP all-gather /
psum_scatter pair around the grouped matmuls vs the fixed-shape
sort-TP pair, across the same a2a matrix.

``run_quant`` (the ``grouped/quant/*`` entries) times the bf16
grouped-EP layer against the int8 / float8_e4m3fn exchange wire
(PR 10): the measured ratios bound the quantize/dequantize overhead on
this CPU container, the emitted predicted α–β saving is the fabric
deliverable the ``payload_dtype="auto"`` policy thresholds on.

``run_overlap`` (the ``grouped_overlap`` suite, ``grouped/overlap/*``
entries) sweeps the overlapped pipeline's chunk count P ∈ {1, 2, 4}
over both a2a modes on the EP mesh — the CPU numbers bound the
pipeline's bookkeeping overhead; the async-overlap win itself is a TPU
quantity (see ``alltoall.cost_pipelined``).

``run_bwd`` (the ``grouped_bwd`` suite) captures TRAINING-step cost,
not just forward dispatch: value_and_grad over the expert FFN with the
Pallas grouped kernels (forward + the dlhs/drhs backward kernels), the
``lax.ragged_dot`` reference, and the capacity-padded sort-path
``expert_ffn`` — the padded-FLOPs baseline the dropless backward beats
on padding alone.  Registered in ``run.py --check`` so perf PRs can't
skip the training-path numbers.
"""
import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import capacity, gating, layout, moe
from repro.core.config import MoEConfig

CFS = (0.5, 1.0, 1.25, 2.0)
EP_WAYS = 4


def run(paper: bool = False):
    d, d_ff, E = (2048, 2048, 16) if paper else (256, 256, 16)
    S = 4096 if paper else 1024
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (S, d), jnp.float32)
    base = MoEConfig(num_experts=E, gate="switch", capacity_factor=1.25)
    params = moe.init_moe_params(key, base, d, d_ff, E, act="relu",
                                 dtype=jnp.float32)

    def layer_fn(cfg):
        @jax.jit
        def fn(x):
            y, aux, _ = moe.moe_block_local(cfg, params, x, num_experts=E,
                                            act="relu")
            return y
        return fn

    for cf in CFS:
        cfgs = {mode: MoEConfig(num_experts=E, gate="switch",
                                capacity_factor=cf, dispatch=mode)
                for mode in ("sort", "dense", "grouped")}
        t = {mode: timeit(layer_fn(cfg), x) for mode, cfg in cfgs.items()}

        # drop rate the padded modes pay at this cf (grouped drops zero)
        g = gating.route(cfgs["sort"],
                         gating.router_logits(cfgs["sort"], x,
                                              params["gate_w"]))
        C = capacity.expert_capacity(cfgs["sort"], S, E)
        plan = layout.plan_sort(g, E, C)
        drop = float(jnp.mean(plan.slot < 0))

        emit(f"grouped/sort/cf{cf}/S{S}", t["sort"],
             f"drop_rate={drop:.1%} capacity={C}")
        emit(f"grouped/dense/cf{cf}/S{S}", t["dense"])
        emit(f"grouped/grouped/cf{cf}/S{S}", t["grouped"],
             f"dropless; vs_sort={t['sort'] / t['grouped']:.2f}x "
             f"vs_dense={t['dense'] / t['grouped']:.2f}x",
             vs_sort=t["sort"] / t["grouped"],
             vs_dense=t["dense"] / t["grouped"],
             sort_drop_rate=drop)

    run_ep(paper=paper)
    run_tp(paper=paper)
    run_quant(paper=paper)


TP_MESH = (2, 4)        # (data=TP, model=EP) — data carries the f slices


def _sharded_setup(mesh_shape, mesh_axes, tp_axis, key_tag, paper: bool,
                   dtype=jnp.float32):
    """Shared setup for the sharded grouped suites (``run_ep``/``run_tp``
    /``run_overlap``/``run_quant``): the smoke mesh, a switch-routed
    token batch, expert params at ``dtype``, and a cfg → jitted-layer
    factory.  Returns None (after printing why) when the backend has
    too few devices."""
    import numpy as np
    n_dev = int(np.prod(mesh_shape))
    if len(jax.devices()) < n_dev:
        # run.py only setdefault()s XLA_FLAGS — a preexisting value in the
        # shell leaves 1 device.  write_json carries the committed
        # grouped/<key_tag>/* entries over un-refreshed; say why.
        print(f"# WARNING: grouped/{key_tag} SKIPPED — "
              f"{len(jax.devices())} device(s) < {n_dev}; committed "
              f"grouped/{key_tag}/* entries will NOT be refreshed "
              f"(unset XLA_FLAGS or include "
              f"--xla_force_host_platform_device_count=8)")
        return None
    from repro.launch.mesh import make_smoke_mesh
    mesh = make_smoke_mesh(mesh_shape, mesh_axes)
    d, d_ff, E = (512, 512, 16) if paper else (128, 128, 16)
    S = 2048 if paper else 512
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (S, d), dtype)
    base = MoEConfig(num_experts=E, gate="switch", capacity_factor=1.25)
    params = moe.init_moe_params(key, base, d, d_ff, E, act="relu",
                                 dtype=dtype)

    def layer_fn(cfg):
        @jax.jit
        def fn(p, v):
            y, _, _ = moe.sharded_moe_apply(mesh, cfg, p, v,
                                            num_experts=E, act="relu",
                                            expert_tp_axis=tp_axis)
            return y
        return fn

    return layer_fn, params, x, E, S


def _run_sharded_matrix(mesh_shape, mesh_axes, tp_axis, key_tag, tag,
                        paper: bool):
    """Shared body of ``run_ep``/``run_tp``: time the full MoE layer for
    the {sort, grouped} × {flat, hierarchical} matrix on the given mesh
    (optionally with expert TP over ``tp_axis``) and emit one entry per
    cell with the grouped-vs-sort / hier-vs-flat ratios."""
    setup = _sharded_setup(mesh_shape, mesh_axes, tp_axis, key_tag, paper)
    if setup is None:
        return
    layer_fn, params, x, E, S = setup

    t = {}
    for mode, a2a in (("sort", "flat"), ("sort", "hierarchical"),
                      ("grouped", "flat"), ("grouped", "hierarchical")):
        cfg = MoEConfig(num_experts=E, gate="switch", capacity_factor=1.25,
                        dispatch=mode, a2a=a2a, a2a_inner=2)
        t[(mode, a2a)] = timeit(layer_fn(cfg), params, x)

    for (mode, a2a), us in t.items():
        ratios = {}
        derived = tag
        if mode == "grouped":
            ratios["vs_sort"] = t[("sort", a2a)] / us
            derived += f"; vs_sort={ratios['vs_sort']:.2f}x"
        if a2a == "hierarchical":
            ratios["vs_flat"] = t[(mode, "flat")] / us
            derived += f"; vs_flat={ratios['vs_flat']:.2f}x"
        emit(f"grouped/{key_tag}/{mode}_{a2a}/S{S}", us, derived, **ratios)


def run_ep(paper: bool = False):
    """Expert-parallel grouped dispatch: the grouped AllToAll (count
    exchange + bounded segments) vs the capacity-padded sort exchange on
    an EP_WAYS-way 'model' mesh, flat and hierarchical.  Absolute µs are
    fake-device CPU numbers; the grouped-vs-sort and hier-vs-flat RATIOS
    are the tracked deliverables."""
    _run_sharded_matrix((EP_WAYS,), ("model",), None,
                        f"ep{EP_WAYS}", f"ep{EP_WAYS}", paper)


def run_tp(paper: bool = False):
    """Expert-TP × grouped-EP (the composition the old code forfeited by
    rewriting grouped+TP to sort): full-layer time with the expert
    weights' f dim sharded over ``data`` while experts shard over
    ``model``, for the whole dispatch × a2a matrix.  The grouped-vs-sort
    and hier-vs-flat RATIOS under TP are the tracked deliverables (on
    TPU the grouped-TP path additionally wins the capacity-padding
    FLOPs back — see core/layout.py's cost model)."""
    _run_sharded_matrix(TP_MESH, ("data", "model"), "data",
                        "tp", f"tp{TP_MESH[0]}xep{TP_MESH[1]}", paper)


QUANT_WIRES = ("int8", "float8_e4m3fn")


def run_quant(paper: bool = False):
    """Quantized exchange wire (PR 10): the full bf16 grouped-EP layer
    with the payload AllToAlls at bf16 vs int8 vs float8_e4m3fn
    (per-chunk scales, f32-accumulating matmuls either side).

    On this CPU container the collectives are emulated, so the measured
    ``vs_bf16`` ratios bound the quantize/dequantize arithmetic overhead
    (it must stay ~1.0×); the fabric-level deliverable is the PREDICTED
    α–β saving of the 1-byte wire on the ici_dcn fabric, emitted
    alongside — the same quantity ``payload_dtype="auto"`` thresholds on
    (``tuning.QUANT_MIN_SAVING``)."""
    from repro.core import tuning

    setup = _sharded_setup((EP_WAYS,), ("model",), None, "quant", paper,
                           dtype=jnp.bfloat16)
    if setup is None:
        return
    layer_fn, params, x, E, S = setup
    T = x.shape[0] // EP_WAYS

    def cfg_for(wire):
        return MoEConfig(num_experts=E, gate="switch", capacity_factor=1.25,
                         dispatch="grouped", payload_dtype=wire)

    prev = tuning.set_tuning(mode="auto", fabric="ici_dcn")
    try:
        plans = {w: tuning.resolve_plan(
            cfg_for(w), model_size=EP_WAYS, tokens_per_shard=T,
            d_model=x.shape[-1], dtype=x.dtype) for w in (None,) + QUANT_WIRES}
    finally:
        tuning.set_tuning(mode=prev[0], fabric=prev[1])

    t_full = timeit(layer_fn(cfg_for(None)), params, x)
    emit(f"grouped/quant/bf16/S{S}", t_full,
         f"full-width wire ({plans[None].payload_bytes / 1e3:.0f}KB)")
    for wire in QUANT_WIRES:
        us = timeit(layer_fn(cfg_for(wire)), params, x)
        saving = (1.0 - plans[wire].cost_flat / plans[None].cost_flat
                  if plans[None].cost_flat else 0.0)
        emit(f"grouped/quant/{wire}/S{S}", us,
             f"1-byte wire ({plans[wire].payload_bytes / 1e3:.0f}KB); "
             f"vs_bf16={t_full / us:.2f}x; "
             f"predicted a2a saving={saving:.0%} (ici_dcn)",
             vs_bf16=t_full / us, predicted_saving=saving)


OVERLAP_SWEEP = (1, 2, 4)


def run_overlap(paper: bool = False):
    """Overlapped (chunked, double-buffered) grouped-EP pipeline: full
    MoE-layer time at ``overlap_chunks`` P ∈ {1, 2, 4} on the EP_WAYS-way
    model mesh, flat and hierarchical.

    On this CPU container collectives execute synchronously, so the
    vs_p1 RATIOS mostly measure the pipeline's bookkeeping overhead
    (window slicing, P× smaller per-call collectives) — the tracked
    floor the real async win must clear; on TPU the steady-state
    exchange hides behind the grouped matmuls and only fill/drain stay
    exposed (``alltoall.cost_pipelined``).  Tracked under ``run.py
    --check`` like every grouped suite.
    """
    setup = _sharded_setup((EP_WAYS,), ("model",), None, "overlap", paper)
    if setup is None:
        return
    layer_fn, params, x, E, S = setup

    t = {}
    for a2a in ("flat", "hierarchical"):
        for P in OVERLAP_SWEEP:
            cfg = MoEConfig(num_experts=E, gate="switch",
                            capacity_factor=1.25, dispatch="grouped",
                            a2a=a2a, a2a_inner=2, overlap_chunks=P)
            t[(a2a, P)] = timeit(layer_fn(cfg), params, x)

    for (a2a, P), us in t.items():
        ratios = {}
        derived = f"ep{EP_WAYS} chunked pipeline"
        if P > 1:
            ratios["vs_p1"] = t[(a2a, 1)] / us
            derived += f"; vs_p1={ratios['vs_p1']:.2f}x"
        emit(f"grouped/overlap/{a2a}/P{P}/S{S}", us, derived, **ratios)


def run_bwd(paper: bool = False):
    """fwd+bwd (value_and_grad) over the grouped expert FFN.

    Segments come from a real switch routing of S tokens, so the ragged
    structure matches what the layer sees; the padded baseline computes
    E·C rows against the grouped paths' Σ n_e = S.  CPU caveats as
    above: ragged_dot lowers serially and the Pallas kernels run in
    interpret mode, so the RATIOS (pallas vs ragged, grouped vs padded
    row counts) are the tracked signal, not absolute µs.
    """
    d, d_ff, E = (2048, 2048, 16) if paper else (256, 256, 16)
    S = 4096 if paper else 512
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (S, d), jnp.float32)
    cfg = MoEConfig(num_experts=E, gate="switch", capacity_factor=1.25)
    params = moe.init_moe_params(key, cfg, d, d_ff, E, act="swiglu",
                                 dtype=jnp.float32)
    ffn_params = {k: v for k, v in params.items() if k != "gate_w"}
    g = gating.route(cfg, gating.router_logits(cfg, x, params["gate_w"]))
    gplan = layout.plan_grouped(g, E)
    xs = layout.dispatch_grouped(x, gplan)
    sizes = gplan.counts

    from repro.kernels.grouped_ffn import grouped_ffn

    def grouped_fn(use_pallas):
        @jax.jit
        def fn(p, xs):
            def loss(p):
                return jnp.sum(grouped_ffn(p, xs, sizes, "swiglu",
                                           use_pallas=use_pallas) ** 2)
            return jax.value_and_grad(loss)(p)
        return fn

    C = capacity.expert_capacity(cfg, S, E)
    plan = layout.plan_sort(g, E, C)
    buf = layout.dispatch_scatter(x, plan, E, C).reshape(E, C, d)

    @jax.jit
    def padded_fn(p, buf):
        def loss(p):
            return jnp.sum(moe.expert_ffn(p, buf, "swiglu") ** 2)
        return jax.value_and_grad(loss)(p)

    t_ragged = timeit(grouped_fn(False), ffn_params, xs)
    t_pallas = timeit(grouped_fn(True), ffn_params, xs)
    t_padded = timeit(padded_fn, ffn_params, buf)
    emit(f"grouped/bwd/ragged/S{S}", t_ragged, f"rows={S}")
    emit(f"grouped/bwd/pallas/S{S}", t_pallas,
         f"fwd+dlhs+drhs kernels; vs_ragged={t_ragged / t_pallas:.2f}x",
         vs_ragged=t_ragged / t_pallas)
    emit(f"grouped/bwd/padded/S{S}", t_padded,
         f"rows={E * C} (capacity-padded); "
         f"vs_ragged={t_ragged / t_padded:.2f}x",
         vs_ragged=t_ragged / t_padded,
         padded_rows_ratio=E * C / S)


if __name__ == "__main__":
    run()
    run_bwd()
