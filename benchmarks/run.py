"""Benchmark harness — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--paper] [--only topk,layout,...]

Output: ``name,us_per_call,derived`` CSV lines on stdout PLUS a
machine-readable ``BENCH_moe.json`` at the repo root (name → µs +
numeric ratios) so the perf trajectory is trackable across PRs without
parsing stdout.  8 fake CPU devices so the AllToAll paths execute;
absolute µs are CPU-emulation numbers — the cross-variant RATIOS and
the α–β model outputs are the deliverables (see EXPERIMENTS.md).
Roofline numbers come from launch/dryrun.py, not from here.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse  # noqa: E402
import json  # noqa: E402
import pathlib  # noqa: E402
import sys  # noqa: E402

FIGS = {"topk": "3", "layout": "4", "alltoall": "7", "breakdown": "1",
        "overall": "8", "grouped": "4+"}

JSON_PATH = pathlib.Path(__file__).resolve().parent.parent / "BENCH_moe.json"


def write_json(wanted) -> None:
    from benchmarks.common import RESULTS
    # merge into any existing file: a partial --only run must refresh its
    # own suites' entries (matched by the recorded "suite" field) without
    # deleting the other suites' tracked numbers (ROADMAP tells future
    # PRs to diff against this file).
    suites, entries = [], {}
    if JSON_PATH.exists():
        try:
            prev = json.loads(JSON_PATH.read_text())
            suites = [s for s in prev.get("suites", []) if s not in wanted]
            entries = {k: v for k, v in prev.get("entries", {}).items()
                       if v.get("suite") not in wanted}
        except (ValueError, OSError):
            pass
    for r in RESULTS:
        entry = {"suite": r["suite"], "us": round(r["us"], 1)}
        if r["derived"]:
            entry["derived"] = r["derived"]
        entry.update(r["ratios"])
        entries[r["name"]] = entry
    JSON_PATH.write_text(json.dumps(
        {"suites": suites + list(wanted), "entries": entries},
        indent=2) + "\n")
    print(f"# wrote {JSON_PATH} ({len(entries)} entries)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper", action="store_true",
                    help="paper-exact dims (slow on CPU)")
    ap.add_argument("--only", default=None,
                    help="comma list: topk,layout,alltoall,breakdown,"
                         "overall,grouped")
    args = ap.parse_args()
    from benchmarks import (bench_alltoall, bench_breakdown, bench_grouped,
                            bench_layout, bench_overall, bench_topk)
    mods = {"topk": bench_topk, "layout": bench_layout,
            "alltoall": bench_alltoall, "breakdown": bench_breakdown,
            "overall": bench_overall, "grouped": bench_grouped}
    wanted = args.only.split(",") if args.only else list(mods)
    print("name,us_per_call,derived")
    from benchmarks.common import RESULTS
    for name in wanted:
        print(f"# --- {name} (paper fig {FIGS[name]}) ---")
        sys.stdout.flush()
        start = len(RESULTS)
        mods[name].run(paper=args.paper)
        for r in RESULTS[start:]:       # tag for the JSON merge
            r["suite"] = name
    write_json(wanted)


if __name__ == '__main__':
    main()
