"""Benchmark harness — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--paper] [--only topk,layout,...]
  PYTHONPATH=src python -m benchmarks.run --check [--only grouped]

Output: ``name,us_per_call,derived`` CSV lines on stdout PLUS a
machine-readable ``BENCH_moe.json`` at the repo root (name → µs +
numeric ratios) so the perf trajectory is trackable across PRs without
parsing stdout.  8 fake CPU devices so the AllToAll paths execute;
absolute µs are CPU-emulation numbers — the cross-variant RATIOS and
the α–β model outputs are the deliverables (see EXPERIMENTS.md).
Roofline numbers come from launch/dryrun.py, not from here.

``--check`` reruns the named suites and DIFFS them against the
committed ``BENCH_moe.json`` instead of rewriting it: entries slower
than the committed number by >25% (tunable via ``--check-factor``) fail
the run (exit 1), so perf PRs regress against tracked numbers, not
eyeballed stdout.  New entries are reported but never fail — commit
them with a plain run first.  Because this container's cpu throttling
shifts WHOLE runs by more than the threshold, each entry is first
normalized by the run-level median drift (see ``check_json``), and
sub-ms entries are reported but never gated — the gate catches code
paths that regressed relative to their run, the same relative signal
the rest of this harness tracks.  Residual per-entry throttling can
still exceed 25% on this box: when an entry you didn't touch trips the
gate, rerun before trusting it, or widen ``--check-factor 1.6`` for
the session.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse  # noqa: E402
import json  # noqa: E402
import pathlib  # noqa: E402
import sys  # noqa: E402

FIGS = {"topk": "3", "layout": "4", "alltoall": "7", "breakdown": "1",
        "overall": "8", "grouped": "4+", "grouped_bwd": "4+ (train step)",
        "grouped_overlap": "4+ (overlapped pipeline)",
        "decode": "4+ (serving decode microbench)",
        "traffic": "4+ (serving workload replay)",
        "tuning": "7+ (auto-tuned dispatch plans vs hand-set knobs)"}

JSON_PATH = pathlib.Path(__file__).resolve().parent.parent / "BENCH_moe.json"


def write_json(wanted) -> None:
    from benchmarks.common import RESULTS
    # merge into any existing file: a partial --only run must refresh its
    # own suites' entries (matched by the recorded "suite" field) without
    # deleting the other suites' tracked numbers (ROADMAP tells future
    # PRs to diff against this file).  Entries of a rerun suite that this
    # run did NOT re-emit are carried over, not deleted — a benchmark
    # section that skipped itself (e.g. bench_grouped.run_ep without
    # enough devices) must not silently erase its tracked trajectory;
    # prune renamed entries by hand.
    suites, entries, prev_wanted = [], {}, {}
    if JSON_PATH.exists():
        try:
            prev = json.loads(JSON_PATH.read_text())
            suites = [s for s in prev.get("suites", []) if s not in wanted]
            for k, v in prev.get("entries", {}).items():
                if v.get("suite") in wanted:
                    prev_wanted[k] = v
                else:
                    entries[k] = v
        except (ValueError, OSError):
            pass
    for r in RESULTS:
        entry = {"suite": r["suite"], "us": round(r["us"], 1)}
        if r["derived"]:
            entry["derived"] = r["derived"]
        entry.update(r["ratios"])
        entries[r["name"]] = entry
        prev_wanted.pop(r["name"], None)
    if prev_wanted:
        print(f"# carried over {len(prev_wanted)} committed entr"
              f"{'y' if len(prev_wanted) == 1 else 'ies'} not re-emitted "
              f"by this run: {', '.join(sorted(prev_wanted))}")
        entries.update(prev_wanted)
    JSON_PATH.write_text(json.dumps(
        {"suites": suites + list(wanted), "entries": entries},
        indent=2) + "\n")
    print(f"# wrote {JSON_PATH} ({len(entries)} entries)")


DEFAULT_CHECK_FACTOR = 1.25
# Entries whose committed time is under this are reported but never fail
# the gate: at sub-ms scale on this 2-CPU container, Python/scheduler
# jitter alone exceeds the regression threshold (observed: ~250µs
# interpret-mode kernels flapping 1.4x between back-to-back runs).
NOISE_FLOOR_US = 1000.0


def check_json(factor: float = DEFAULT_CHECK_FACTOR) -> int:
    """Diff this run's RESULTS (already filtered to the suites that ran)
    against the committed BENCH_moe.json.

    The container's cpu-shares throttling shifts WHOLE runs by well over
    the threshold (observed 1.6× on 40ms entries), so absolute µs can't
    gate directly — consistent with this harness's contract that only
    cross-variant ratios transfer.  Each entry's new/old ratio is
    therefore normalized by the run-level MEDIAN ratio (the machine
    drift): an entry fails only when it is ``factor``× slower than the
    rest of its run moved together, i.e. a real relative regression in
    that code path.  Returns the exit code: 1 iff any gated entry fails.
    """
    from benchmarks.common import RESULTS
    if not JSON_PATH.exists():
        print(f"# --check: no {JSON_PATH} to diff against — run without "
              f"--check first and commit it")
        return 2                        # setup error, not a regression
    try:
        prev = json.loads(JSON_PATH.read_text()).get("entries", {})
    except (ValueError, OSError) as e:
        print(f"# --check: cannot read {JSON_PATH}: {e}")
        return 2                        # setup error, not a regression
    # drift from the gated (≥ noise floor) entries only — the sub-ms ones
    # are declared noise-dominated, so they must not steer the baseline
    ratios = sorted(r["us"] / prev[r["name"]]["us"] for r in RESULTS
                    if prev.get(r["name"], {}).get("us", 0) >= NOISE_FLOOR_US)
    drift = ratios[len(ratios) // 2] if ratios else 1.0
    print(f"# machine drift (median new/old): {drift:.2f}x "
          f"across {len(ratios)} gated entries")
    regressions = []
    for r in RESULTS:
        old = prev.get(r["name"])
        if old is None or "us" not in old:
            print(f"# {'NEW':11s}{r['name']}: {r['us']:.1f}us (untracked — "
                  f"commit with a plain run)")
            continue
        ratio = (r["us"] / old["us"] / drift) if old["us"] else float("inf")
        slow = ratio > factor
        gated = old["us"] >= NOISE_FLOOR_US
        tag = ("REGRESSION" if slow and gated
               else "noisy" if slow else "ok")
        print(f"# {tag:11s}{r['name']}: {old['us']:.1f}us -> "
              f"{r['us']:.1f}us ({ratio:.2f}x drift-normalized)")
        if slow and gated:
            regressions.append((r["name"], ratio))
    if regressions:
        print(f"# --check FAILED: {len(regressions)} entr"
              f"{'y' if len(regressions) == 1 else 'ies'} regressed "
              f">{factor - 1:.0%} beyond machine drift vs committed "
              f"BENCH_moe.json")
        return 1
    print(f"# --check ok: no regression >{factor - 1:.0%} beyond machine "
          f"drift across {len(RESULTS)} entries")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper", action="store_true",
                    help="paper-exact dims (slow on CPU)")
    ap.add_argument("--only", default=None,
                    help="comma list of suites: " + ",".join(FIGS))
    ap.add_argument("--check", action="store_true",
                    help="diff against committed BENCH_moe.json instead of "
                         "rewriting it; exit 1 on regression")
    ap.add_argument("--check-factor", type=float,
                    default=DEFAULT_CHECK_FACTOR,
                    help="slowdown ratio that counts as a regression "
                         "(default 1.25; widen on noisy machines)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="read/write this JSON instead of the committed "
                         "BENCH_moe.json (tooling tests of the gate itself "
                         "— tests/test_bench_gate.py — point it at a "
                         "scratch file)")
    args = ap.parse_args()
    if args.json:
        global JSON_PATH
        JSON_PATH = pathlib.Path(args.json)
    from benchmarks import (bench_alltoall, bench_breakdown, bench_decode,
                            bench_grouped, bench_layout, bench_overall,
                            bench_topk, bench_traffic, bench_tuning)
    # suite name → run callable; grouped_bwd is the fwd+bwd training-path
    # suite (bench_grouped.run_bwd) — part of the default list and thus
    # of the --check regression gate, so perf PRs can't silently skip it;
    # decode/traffic are the serving-side suites (step-builder decode
    # microbench + SlotServer workload replay)
    mods = {"topk": bench_topk.run, "layout": bench_layout.run,
            "alltoall": bench_alltoall.run, "breakdown": bench_breakdown.run,
            "overall": bench_overall.run, "grouped": bench_grouped.run,
            "grouped_bwd": bench_grouped.run_bwd,
            "grouped_overlap": bench_grouped.run_overlap,
            "decode": bench_decode.run, "traffic": bench_traffic.run,
            "tuning": bench_tuning.run}
    wanted = args.only.split(",") if args.only else list(mods)
    unknown = [w for w in wanted if w not in mods]
    if unknown:
        ap.error(f"unknown suite(s) {','.join(unknown)}; "
                 f"available: {','.join(mods)}")
    if args.check and not JSON_PATH.exists():
        # fail before burning minutes of benchmarking on a setup error
        print(f"# --check: no {JSON_PATH} to diff against — run without "
              f"--check first and commit it")
        sys.exit(1)
    print("name,us_per_call,derived")
    from benchmarks.common import RESULTS

    def run_suites():
        for name in wanted:
            print(f"# --- {name} (paper fig {FIGS[name]}) ---")
            sys.stdout.flush()
            start = len(RESULTS)
            mods[name](paper=args.paper)
            for r in RESULTS[start:]:       # tag for the JSON merge
                r["suite"] = name

    run_suites()
    if args.check:
        code = check_json(args.check_factor)
        if code == 1:
            # a throttled container can fake a regression in any single
            # measurement; a REAL one persists.  Remeasure once and gate
            # on the best of the two runs.  (Setup errors — code 2 —
            # exit immediately.)
            print("# --check: remeasuring once to rule out throttling "
                  "noise (gating on best-of-2)")
            best = {r["name"]: r["us"] for r in RESULTS}
            RESULTS.clear()
            run_suites()
            for r in RESULTS:
                r["us"] = min(r["us"], best.get(r["name"], r["us"]))
            code = check_json(args.check_factor)
        sys.exit(code)
    write_json(wanted)


if __name__ == '__main__':
    main()
