"""Benchmark harness — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--paper] [--only topk,layout,...]

Output: ``name,us_per_call,derived`` CSV lines.  8 fake CPU devices so
the AllToAll paths execute; absolute µs are CPU-emulation numbers — the
cross-variant RATIOS and the α–β model outputs are the deliverables
(see EXPERIMENTS.md).  Roofline numbers come from launch/dryrun.py, not
from here.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse  # noqa: E402
import sys  # noqa: E402

FIGS = {"topk": "3", "layout": "4", "alltoall": "7", "breakdown": "1",
        "overall": "8"}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper", action="store_true",
                    help="paper-exact dims (slow on CPU)")
    ap.add_argument("--only", default=None,
                    help="comma list: topk,layout,alltoall,breakdown,overall")
    args = ap.parse_args()
    from benchmarks import (bench_alltoall, bench_breakdown, bench_layout,
                            bench_overall, bench_topk)
    mods = {"topk": bench_topk, "layout": bench_layout,
            "alltoall": bench_alltoall, "breakdown": bench_breakdown,
            "overall": bench_overall}
    wanted = args.only.split(",") if args.only else list(mods)
    print("name,us_per_call,derived")
    for name in wanted:
        print(f"# --- {name} (paper fig {FIGS[name]}) ---")
        sys.stdout.flush()
        mods[name].run(paper=args.paper)


if __name__ == '__main__':
    main()
