"""Paper Fig. 3 — gating top-k operator: HetuMoE's specialized kernel vs
the framework-generic sort-based top-k.

Three variants over (num_tokens × num_experts) grids:
  sort      jax.lax.top_k (XLA's generic sort-based path = the PyTorch
            baseline's role in Fig. 3)
  itermax   the O(k·E) iterative-max formulation (what the Pallas kernel
            computes, here as plain XLA ops)
  pallas    the fused kernel in interpret mode (correctness path; its
            TPU speedup comes from fusing softmax stats + selection into
            one VMEM pass — see kernels/topk_gate.py)
"""
import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core.gating import _topk
from repro.kernels.topk_gate import fused_topk_gate


def run(paper: bool = False):
    grids = [(4096, 16), (16384, 16), (4096, 64), (16384, 64), (4096, 128)]
    if not paper:
        grids = [(1024, 16), (4096, 16), (1024, 64), (1024, 128)]
    for k in (1, 2):
        for S, E in grids:
            logits = jax.random.normal(jax.random.PRNGKey(0), (S, E))

            sort_fn = jax.jit(lambda x: jax.lax.top_k(x, k))
            iter_fn = jax.jit(lambda x: _topk(x, k))
            t_sort = timeit(sort_fn, logits)
            t_iter = timeit(iter_fn, logits)
            emit(f"topk/sort/k{k}/S{S}/E{E}", t_sort, "")
            emit(f"topk/itermax/k{k}/S{S}/E{E}", t_iter,
                 f"speedup_vs_sort={t_sort / t_iter:.2f}x")
        # pallas interpret once per k (slow python loop — structural check)
        S, E = grids[0]
        logits = jax.random.normal(jax.random.PRNGKey(0), (S, E))
        t_p = timeit(lambda x: fused_topk_gate(x, k, interpret=True), logits,
                     warmup=1, iters=2)
        emit(f"topk/pallas-interpret/k{k}/S{S}/E{E}", t_p,
             "interpret-mode (CPU python loop; TPU perf via fusion)")


if __name__ == "__main__":
    run()
