"""Paper Fig. 4 — data layout transformation: HetuMoE's sort/scatter
kernel path vs the dense one-hot einsum (DeepSpeed/GShard baseline),
plus the Pallas layout kernel's blocked tiling vs the seed's
row-per-step tiling.

The dense path does O(S·E·C·d) MACs; the sort path does O(S·K log) index
work + O(S·K·d) data movement — the asymptotic gap the paper's >26%
kernel win comes from.  Within the sort path, the blocked kernel moves
``block_m`` rows per grid step off one scalar-prefetched index slab
instead of one (1, d) DMA per step.
"""
import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import capacity, gating, layout
from repro.core.config import MoEConfig
from repro.kernels.layout_transform import gather_rows, gather_rows_rowstep


def run(paper: bool = False):
    E, d = 16, 2048 if paper else 512
    sizes = [4096, 16384] if paper else [1024, 4096]
    cfg = MoEConfig(num_experts=E, gate="switch", capacity_factor=1.25)
    for S in sizes:
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (S, d), jnp.float32)
        logits = jax.random.normal(key, (S, E))
        C = capacity.expert_capacity(cfg, S, E)

        @jax.jit
        def sort_path(x, logits):
            g = gating.route(cfg, logits)
            plan = layout.plan_sort(g, E, C)
            buf = layout.dispatch_scatter(x, plan, E, C)
            return layout.combine_gather(buf, plan)

        @jax.jit
        def dense_path(x, logits):
            g = gating.route(cfg, logits)
            plan = layout.plan_cumsum(g, E, C)
            buf = layout.dispatch_dense(x, plan, E, C)
            return layout.combine_dense(buf, plan, E, C)

        t_s = timeit(sort_path, x, logits)
        t_d = timeit(dense_path, x, logits)
        emit(f"layout/sort/S{S}/E{E}/d{d}", t_s,
             f"speedup_vs_dense={t_d / t_s:.2f}x",
             speedup_vs_dense=t_d / t_s)
        emit(f"layout/dense/S{S}/E{E}/d{d}", t_d,
             f"flops_ratio=O(S*E*C*d)/O(S*K*d)={E * C // max(S // S, 1) // 1}C-vs-K")

        if S == sizes[0]:
            # kernel tiling comparison on the acceptance config (16e,
            # S=1024 off --paper): blocked vs the seed's row-per-step.
            # Row-per-step is O(grid)=E·C steps and brutally slow in
            # interpret mode too, so only the smallest size times it.
            g = gating.route(cfg, logits)
            plan = layout.plan_sort(g, E, C)
            inv = plan.inv
            t_blk = timeit(lambda: gather_rows(x, inv, True))
            t_row = timeit(lambda: gather_rows_rowstep(x, inv, interpret=True))
            emit(f"layout/kernel-blocked/S{S}/E{E}/d{d}", t_blk,
                 f"speedup_vs_rowstep={t_row / t_blk:.2f}x",
                 speedup_vs_rowstep=t_row / t_blk)
            emit(f"layout/kernel-rowstep/S{S}/E{E}/d{d}", t_row,
                 "seed tiling: one (1,d) DMA per grid step")


if __name__ == "__main__":
    run()
