"""Kill-and-resume: a crashed run restored from the newest intact
checkpoint must reproduce the uninterrupted loss trajectory BITWISE
(synthetic data + rng are keyed by the global step, the lr schedule by
``state.step``).  Fast path crashes in-process via the fault harness;
the slow-marked test SIGKILLs a real subprocess mid-checkpoint-save."""
import json
import os
import signal
import subprocess
import sys

import pytest

from repro.core import faults as F
from repro.launch import mesh as mesh_lib
from repro.launch.train import run as train_run

ARCH = "starcoder2-3b"
KW = dict(batch=2, seq=16, smoke=True, log_every=100)
METRIC_KEYS = ("loss", "ce", "aux", "grad_norm", "lr")
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _assert_bitwise_equal_tail(ref_hist, res_hist, start):
    """res_hist (resumed, steps start..end) must equal ref_hist[start:]
    exactly — float equality, no tolerance."""
    assert [h["step"] for h in res_hist] == [h["step"]
                                             for h in ref_hist[start:]]
    for ref, res in zip(ref_hist[start:], res_hist):
        for k in METRIC_KEYS:
            assert ref[k] == res[k], (
                f"step {ref['step']} {k}: {ref[k]!r} != {res[k]!r} — resume "
                f"is not bitwise-reproducing the uninterrupted run")


def test_crash_and_resume_bitwise(tmp_path, mesh1):
    ckpt = str(tmp_path / "ckpt")
    # uninterrupted reference trajectory
    _, ref = train_run(ARCH, steps=8, **KW)
    # crash (simulated preemption) at the top of step 5; saves at 3 and 6
    plan = F.FaultPlan(sites={"train.loop": F.FaultSpec(steps=(5,),
                                                        mode="raise")})
    with pytest.raises(F.FaultInjected):
        train_run(ARCH, steps=8, ckpt_dir=ckpt, ckpt_every=3, faults=plan,
                  **KW)
    # resume restores step 3 and replays 3..7 bitwise
    _, resumed = train_run(ARCH, steps=8, ckpt_dir=ckpt, resume=True, **KW)
    assert resumed[0]["step"] == 3
    _assert_bitwise_equal_tail(ref, resumed, start=3)


def test_resume_without_checkpoint_starts_fresh(tmp_path, mesh1):
    ckpt = str(tmp_path / "empty")
    _, hist = train_run(ARCH, steps=2, ckpt_dir=ckpt, resume=True, **KW)
    assert [h["step"] for h in hist] == [0, 1]


def test_ckpt_flags_require_ckpt_dir():
    with pytest.raises(ValueError, match="ckpt-dir"):
        train_run(ARCH, steps=2, ckpt_every=1, **KW)
    with pytest.raises(ValueError, match="ckpt-dir"):
        train_run(ARCH, steps=2, resume=True, **KW)


def test_driver_fails_fast_on_persistent_nonfinite(mesh1):
    """Every step non-finite → every step skipped → the driver aborts
    after max_skipped_steps consecutive skips instead of spinning."""
    plan = F.FaultPlan(sites={"train.grads": F.FaultSpec(mode="nan",
                                                         always=True)})
    with pytest.raises(RuntimeError, match="consecutive non-finite"):
        train_run(ARCH, steps=60, faults=plan, **KW)


# -- launch hardening (--mesh parsing) --------------------------------------

def test_parse_mesh_valid():
    assert mesh_lib.parse_mesh("1x1") == (1, 1)
    assert mesh_lib.parse_mesh("16x16") == (16, 16)
    assert mesh_lib.parse_mesh("2x16x16") == (2, 16, 16)


@pytest.mark.parametrize("bad", ["16x", "x4", "axb", "0x4", "2x-1", ""])
def test_parse_mesh_invalid(bad):
    with pytest.raises(ValueError, match="DxM"):
        mesh_lib.parse_mesh(bad)


# -- real SIGKILL mid-save, via the CLI -------------------------------------

def _train_cli(tmp_path, *extra):
    env = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch", ARCH,
           "--smoke", "--steps", "8", "--batch", "2", "--seq", "16",
           "--log-every", "100", *extra]
    return subprocess.run(cmd, cwd=str(tmp_path), env=env,
                          capture_output=True, text=True, timeout=600)


@pytest.mark.slow
def test_sigkill_during_save_then_resume_bitwise(tmp_path):
    """End-to-end through the CLI: SIGKILL the process between the
    checkpoint tmp-file fsync and its os.replace (the worst torn-write
    window), then --resume and diff --history-out JSON against an
    uninterrupted run — bitwise."""
    ckpt = str(tmp_path / "ckpt")
    ref = _train_cli(tmp_path, "--history-out", "ref.json")
    assert ref.returncode == 0, ref.stderr
    # step-6 save is killed mid-write: tmp fsynced, .npz never replaced
    crashed = _train_cli(tmp_path, "--ckpt-dir", ckpt, "--ckpt-every", "3",
                         "--inject", "ckpt.data_tmp_written:kill@6")
    assert crashed.returncode == -signal.SIGKILL
    resumed = _train_cli(tmp_path, "--ckpt-dir", ckpt, "--resume",
                         "--history-out", "res.json")
    assert resumed.returncode == 0, resumed.stderr
    assert "resumed from step 3" in resumed.stdout
    with open(tmp_path / "ref.json") as f:
        ref_hist = json.load(f)["history"]
    with open(tmp_path / "res.json") as f:
        res = json.load(f)
    assert res["resumed"] and res["start"] == 3
    _assert_bitwise_equal_tail(ref_hist, res["history"], start=3)
