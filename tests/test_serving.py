"""Serving path: prefill+decode consistency with the full forward pass,
the step-builder compiled-step cache (no re-jitting across calls), and
grouped-dispatch decode equivalence (generate ≡ SlotServer, grouped ≡
sort ≡ dense)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import capacity
from repro.core.config import DISPATCH_MODES
from repro.models import transformer as T
from repro.serving import Request, SlotServer, engine, generate
from repro.serving.engine import make_prefill_step, make_serve_step

RNG = jax.random.PRNGKey(9)


@pytest.mark.parametrize("arch", ["yi-6b", "h2o-danube-3-4b", "zamba2-7b",
                                  "rwkv6-1.6b", "dbrx-132b"])
def test_prefill_then_decode_matches_full_forward(arch, mesh1):
    """logits(prefill(x[:-1]) → decode(x[-1])) == logits(forward(x))[-1]."""
    cfg = configs.smoke_config(arch).replace(dtype="float32")
    p = T.init_model(RNG, cfg)
    B, S = 2, 12
    toks = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
    h, _, _ = T.forward(p, toks, cfg, mesh=mesh1)
    full_logits = T.logits_from_hidden(p, cfg, h, mesh1)
    prefill = make_prefill_step(cfg, mesh1, cache_len=S + 4)
    step = make_serve_step(cfg, mesh1)
    lg, caches = prefill(p, toks[:, :-1])
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(full_logits[:, -2]),
                               rtol=2e-3, atol=2e-3)
    lg2, _ = step(p, toks[:, -1:], caches)
    np.testing.assert_allclose(np.asarray(lg2[:, 0]),
                               np.asarray(full_logits[:, -1]),
                               rtol=2e-3, atol=2e-3)


def test_generate_greedy_deterministic(mesh1):
    cfg = configs.smoke_config("starcoder2-3b").replace(dtype="float32")
    p = T.init_model(RNG, cfg)
    prompt = jax.random.randint(RNG, (2, 8), 0, cfg.vocab_size)
    a = generate(p, cfg, prompt, steps=6, mesh=mesh1)
    b = generate(p, cfg, prompt, steps=6, mesh=mesh1)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert a.shape == (2, 14)


def test_generate_rejects_encoder_only(mesh1):
    cfg = configs.smoke_config("hubert-xlarge")
    p = T.init_model(RNG, cfg)
    with pytest.raises(AssertionError):
        generate(p, cfg, jnp.zeros((1, 4), jnp.int32), steps=2, mesh=mesh1)


# ---------------------------------------------------------------------------
# step-builder cache: repeated generate() calls must NOT re-jit
# ---------------------------------------------------------------------------

def test_generate_reuses_compiled_steps(mesh1):
    """The seed behaviour jitted fresh closures per generate() call; the
    step-builder cache must trace prefill and decode exactly once for
    identical shapes, and a second call must not add retraces."""
    cfg = configs.smoke_config("starcoder2-3b").replace(dtype="float32")
    p = T.init_model(RNG, cfg)
    prompt = jax.random.randint(RNG, (2, 8), 0, cfg.vocab_size)
    engine.clear_step_cache()
    a = generate(p, cfg, prompt, steps=5, mesh=mesh1)
    counts_after_first = dict(engine.trace_counts)
    assert counts_after_first, "trace probe recorded nothing"
    assert all(v == 1 for v in counts_after_first.values()), counts_after_first
    b = generate(p, cfg, prompt, steps=5, mesh=mesh1)
    assert dict(engine.trace_counts) == counts_after_first, \
        "second identical generate() retraced"
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_distinct_shapes_get_distinct_cached_steps(mesh1):
    cfg = configs.smoke_config("starcoder2-3b").replace(dtype="float32")
    engine.clear_step_cache()
    s1 = engine.build_decode(cfg, mesh1, batch=2)
    s2 = engine.build_decode(cfg, mesh1, batch=2)
    s3 = engine.build_decode(cfg, mesh1, batch=4)
    assert s1 is s2 and s1 is not s3


# ---------------------------------------------------------------------------
# grouped decode: generate ≡ sort ≡ dense, SlotServer ≡ generate
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mesh_name", ["mesh1", "mesh_ep4"])
def test_generate_grouped_matches_sort_and_dense(mesh_name, request):
    """Decode-shaped routing equivalence end to end: the same prompt
    generates the same token sequence under every dispatch mode."""
    mesh = request.getfixturevalue(mesh_name)
    cfg = configs.smoke_config("dbrx-132b").replace(dtype="float32")
    p = T.init_model(RNG, cfg)
    prompt = jax.random.randint(RNG, (2, 6), 0, cfg.vocab_size)
    outs = {d: np.asarray(generate(p, cfg, prompt, steps=5, mesh=mesh,
                                   dispatch=d))
            for d in DISPATCH_MODES}
    for d in DISPATCH_MODES:
        np.testing.assert_array_equal(outs[d], outs["dense"],
                                      err_msg=f"dispatch={d} vs dense")


def test_slot_server_grouped_bitwise_matches_generate(mesh1):
    """SlotServer under dispatch='grouped' emits per-token outputs
    bitwise identical to batch-1 generate() under grouped on every
    healthy slot (the PR's acceptance bar)."""
    cfg = configs.smoke_config("dbrx-132b").replace(dtype="float32")
    p = T.init_model(RNG, cfg)
    gen = 4
    prompts = [jax.random.randint(jax.random.fold_in(RNG, i), (6,), 0,
                                  cfg.vocab_size) for i in range(3)]
    refs = [np.asarray(generate(p, cfg, pr[None, :], steps=gen, mesh=mesh1,
                                dispatch="grouped"))[0, 6:] for pr in prompts]
    srv = SlotServer(cfg, p, slots=2, cache_len=6 + gen + 2, mesh=mesh1,
                     dispatch="grouped")
    assert srv.cfg.moe.dispatch == "grouped"
    done = srv.run([Request(uid=i, prompt=pr, max_new=gen)
                    for i, pr in enumerate(prompts)])
    assert sorted(r.uid for r in done) == [0, 1, 2]
    for r in done:
        assert r.status == "ok", (r.uid, r.status, r.error)
        np.testing.assert_array_equal(np.asarray(r.out), refs[r.uid],
                                      err_msg=f"uid={r.uid}")


# ---------------------------------------------------------------------------
# build-time validation: dispatch names + grouped bounds
# ---------------------------------------------------------------------------

def test_dispatch_override_validated():
    cfg = configs.smoke_config("dbrx-132b")
    with pytest.raises(ValueError) as ei:
        engine.serve_config(cfg, dispatch="banana")
    assert all(m in str(ei.value) for m in DISPATCH_MODES)
    # no override → config untouched; matching override → same config
    assert engine.serve_config(cfg) is cfg
    assert engine.serve_config(cfg, dispatch=cfg.moe.dispatch) is cfg
    got = engine.serve_config(cfg, dispatch="grouped")
    assert got.moe.dispatch == "grouped"


def test_payload_dtype_override_validated():
    """PR 10: ``serve_config(payload_dtype=)`` threads the quantized
    exchange wire through MoEConfig validation — bad names raise naming
    the knob, matching overrides stay the identity config."""
    cfg = configs.smoke_config("dbrx-132b")
    with pytest.raises(ValueError, match="payload_dtype"):
        engine.serve_config(cfg, payload_dtype="int7")
    got = engine.serve_config(cfg, dispatch="grouped", payload_dtype="int8")
    assert got.moe.dispatch == "grouped"
    assert got.moe.payload_dtype == "int8"
    assert engine.serve_config(got, payload_dtype="int8") is got
    # dense architectures have no wire to quantize
    dense = configs.smoke_config("starcoder2-3b")
    with pytest.raises(ValueError, match="payload_dtype"):
        engine.serve_config(dense, payload_dtype="int8")


def test_dispatch_override_rejected_for_dense_arch(mesh1):
    cfg = configs.smoke_config("starcoder2-3b")
    p = T.init_model(RNG, cfg)
    with pytest.raises(ValueError, match="no MoE"):
        generate(p, cfg, jnp.zeros((1, 4), jnp.int32), steps=2, mesh=mesh1,
                 dispatch="grouped")


def test_grouped_overlap_bound_fails_at_build_time(mesh1):
    """A decode batch whose grouped segment bound is not divisible by
    overlap_chunks must raise at step-BUILD/server-construction time
    (ValueError), not as a trace-time assertion inside shard_map."""
    cfg = configs.smoke_config("dbrx-132b").replace(dtype="float32")
    B = capacity.grouped_tp_gather_bound(cfg.moe, 1)   # batch=1 decode
    bad = cfg.replace(moe=dataclasses.replace(
        cfg.moe, dispatch="grouped", overlap_chunks=B + 1))
    with pytest.raises(ValueError, match="overlap"):
        engine.validate_decode_config(bad, mesh1, 1)
    p = T.init_model(RNG, cfg)
    with pytest.raises(ValueError, match="overlap"):
        SlotServer(bad, p, slots=1, cache_len=8, mesh=mesh1)
    with pytest.raises(ValueError, match="overlap"):
        generate(p, bad, jnp.zeros((1, 4), jnp.int32), steps=2, mesh=mesh1)


def test_validate_decode_config_rejects_bad_shapes(mesh1):
    cfg = configs.smoke_config("dbrx-132b")
    with pytest.raises(ValueError, match="batch"):
        engine.validate_decode_config(cfg, mesh1, 0)
    with pytest.raises(ValueError, match="cache_len"):
        engine.validate_decode_config(cfg, mesh1, 1, cache_len=1)


# ---------------------------------------------------------------------------
# launch/serve.py CLI: --dispatch flag
# ---------------------------------------------------------------------------

def test_serve_cli_dispatch_arg_validated():
    import argparse

    from repro.launch.serve import dispatch_cli_arg
    assert dispatch_cli_arg("grouped") == "grouped"
    assert dispatch_cli_arg("sort") == "sort"
    with pytest.raises(argparse.ArgumentTypeError) as ei:
        dispatch_cli_arg("groupd")
    assert all(m in str(ei.value) for m in DISPATCH_MODES)


def test_serve_driver_logs_dispatch_mode(capsys):
    from repro.launch.serve import run
    out = run("dbrx-132b", smoke=True, batch=2, prompt_len=4, gen=2,
              dispatch="grouped")
    assert out.shape == (2, 6)
    printed = capsys.readouterr().out
    assert "dispatch=grouped (flag)" in printed
