"""Serving path: prefill+decode consistency with the full forward pass."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as T
from repro.serving import generate
from repro.serving.engine import make_prefill_step, make_serve_step

RNG = jax.random.PRNGKey(9)


@pytest.mark.parametrize("arch", ["yi-6b", "h2o-danube-3-4b", "zamba2-7b",
                                  "rwkv6-1.6b", "dbrx-132b"])
def test_prefill_then_decode_matches_full_forward(arch, mesh1):
    """logits(prefill(x[:-1]) → decode(x[-1])) == logits(forward(x))[-1]."""
    cfg = configs.smoke_config(arch).replace(dtype="float32")
    p = T.init_model(RNG, cfg)
    B, S = 2, 12
    toks = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
    h, _, _ = T.forward(p, toks, cfg, mesh=mesh1)
    full_logits = T.logits_from_hidden(p, cfg, h, mesh1)
    prefill = make_prefill_step(cfg, mesh1, cache_len=S + 4)
    step = make_serve_step(cfg, mesh1)
    lg, caches = prefill(p, toks[:, :-1])
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(full_logits[:, -2]),
                               rtol=2e-3, atol=2e-3)
    lg2, _ = step(p, toks[:, -1:], caches)
    np.testing.assert_allclose(np.asarray(lg2[:, 0]),
                               np.asarray(full_logits[:, -1]),
                               rtol=2e-3, atol=2e-3)


def test_generate_greedy_deterministic(mesh1):
    cfg = configs.smoke_config("starcoder2-3b").replace(dtype="float32")
    p = T.init_model(RNG, cfg)
    prompt = jax.random.randint(RNG, (2, 8), 0, cfg.vocab_size)
    a = generate(p, cfg, prompt, steps=6, mesh=mesh1)
    b = generate(p, cfg, prompt, steps=6, mesh=mesh1)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert a.shape == (2, 14)


def test_generate_rejects_encoder_only(mesh1):
    cfg = configs.smoke_config("hubert-xlarge")
    p = T.init_model(RNG, cfg)
    with pytest.raises(AssertionError):
        generate(p, cfg, jnp.zeros((1, 4), jnp.int32), steps=2, mesh=mesh1)
