"""Hierarchical AllToAll (paper §3.2, Figs. 5–7): functional equivalence
with flat AllToAll + the α–β cost model that captures the paper's win."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import alltoall
from repro.core.compat import shard_map

RNG = jax.random.PRNGKey(2)


def _run(mesh_model8, fn):
    return jax.jit(shard_map(fn, mesh=mesh_model8, in_specs=P("model"),
                                 out_specs=P("model"), check_vma=False))


@pytest.mark.parametrize("inner,outer", [(2, 4), (4, 2), (8, 1), (1, 8)])
def test_hierarchical_equals_flat(mesh_model8, inner, outer):
    x = jax.random.normal(RNG, (64, 4, 16))     # per-device (8, 4, 16)
    flat = _run(mesh_model8, lambda v: alltoall.flat_all_to_all(v, "model"))
    hier = _run(mesh_model8, lambda v: alltoall.all_to_all(
        v, "model", mode="hierarchical", inner=inner, outer=outer))
    np.testing.assert_allclose(np.asarray(flat(x)), np.asarray(hier(x)),
                               rtol=1e-6)


def test_alltoall_is_involution_on_permutation(mesh_model8):
    """a2a twice returns the original (chunk i->j then j->i)."""
    x = jax.random.normal(RNG, (64, 4, 8))
    f = _run(mesh_model8, lambda v: alltoall.flat_all_to_all(
        alltoall.flat_all_to_all(v, "model"), "model"))
    np.testing.assert_allclose(np.asarray(f(x)), np.asarray(x), rtol=1e-6)


def test_hierarchical_gradient(mesh_model8):
    x = jax.random.normal(RNG, (64, 4, 8))

    def loss(v):
        out = shard_map(
            lambda u: alltoall.hierarchical_all_to_all(u, "model", inner=4,
                                                       outer=2),
            mesh=mesh_model8, in_specs=P("model"), out_specs=P("model"),
            check_vma=False)(v)
        return jnp.sum(out ** 2)

    g = jax.jit(jax.grad(loss))(x)
    # a2a is a permutation → grad of sum-of-squares is 2x permuted back = 2x
    np.testing.assert_allclose(np.asarray(g), 2 * np.asarray(x), rtol=1e-6)


def test_cost_model_paper_regime():
    """Paper Fig. 7 regime: N nodes × G GPUs, 1 NIC — hierarchical wins
    and the advantage grows with node count (1.66× at 4×8 → 2× at 8×8)."""
    B = 16e6                                      # 16 MB per device (paper)
    s4 = alltoall.cost_flat(B, 4, 8, alltoall.PCIE, alltoall.ETH100) / \
        alltoall.cost_hierarchical(B, 4, 8, alltoall.PCIE, alltoall.ETH100)
    s8 = alltoall.cost_flat(B, 8, 8, alltoall.PCIE, alltoall.ETH100) / \
        alltoall.cost_hierarchical(B, 8, 8, alltoall.PCIE, alltoall.ETH100)
    assert 1.2 < s4 < 3.0, s4       # paper: 1.66× at 4×8
    assert s4 < s8 < 4.0, (s4, s8)  # paper: 2× at 8×8 — grows with N


def test_cost_model_message_aggregation():
    """The mechanism: G× fewer inter-node messages, G× larger each,
    identical NIC bytes — the win is pure per-message overhead."""
    B, N, G = 16e6, 8, 8
    M = N * G
    # message counts through one NIC
    assert G * (N - 1) == G * G * (N - 1) / G
    # message sizes: B/(G·N) flat → B/N hier (paper: G² aggregation of
    # the per-GPU-pair chunks into per-node bundles)
    assert (B / N) / (B / M) == G
    # NIC bytes identical
    flat_bytes = G * (M - G) / M * B
    hier_bytes = G * (N - 1) / N * B
    assert abs(flat_bytes - hier_bytes) < 1e-6
