"""Hierarchical AllToAll (paper §3.2, Figs. 5–7): functional equivalence
with flat AllToAll + the α–β cost model that captures the paper's win."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import alltoall
from repro.core.compat import shard_map

RNG = jax.random.PRNGKey(2)


def _run(mesh_model8, fn):
    return jax.jit(shard_map(fn, mesh=mesh_model8, in_specs=P("model"),
                                 out_specs=P("model"), check_vma=False))


@pytest.mark.parametrize("inner,outer", [(2, 4), (4, 2), (8, 1), (1, 8)])
def test_hierarchical_equals_flat(mesh_model8, inner, outer):
    x = jax.random.normal(RNG, (64, 4, 16))     # per-device (8, 4, 16)
    flat = _run(mesh_model8, lambda v: alltoall.flat_all_to_all(v, "model"))
    hier = _run(mesh_model8, lambda v: alltoall.all_to_all(
        v, "model", mode="hierarchical", inner=inner, outer=outer))
    np.testing.assert_allclose(np.asarray(flat(x)), np.asarray(hier(x)),
                               rtol=1e-6)


def test_alltoall_is_involution_on_permutation(mesh_model8):
    """a2a twice returns the original (chunk i->j then j->i)."""
    x = jax.random.normal(RNG, (64, 4, 8))
    f = _run(mesh_model8, lambda v: alltoall.flat_all_to_all(
        alltoall.flat_all_to_all(v, "model"), "model"))
    np.testing.assert_allclose(np.asarray(f(x)), np.asarray(x), rtol=1e-6)


def test_hierarchical_gradient(mesh_model8):
    x = jax.random.normal(RNG, (64, 4, 8))

    def loss(v):
        out = shard_map(
            lambda u: alltoall.hierarchical_all_to_all(u, "model", inner=4,
                                                       outer=2),
            mesh=mesh_model8, in_specs=P("model"), out_specs=P("model"),
            check_vma=False)(v)
        return jnp.sum(out ** 2)

    g = jax.jit(jax.grad(loss))(x)
    # a2a is a permutation → grad of sum-of-squares is 2x permuted back = 2x
    np.testing.assert_allclose(np.asarray(g), 2 * np.asarray(x), rtol=1e-6)


def test_cost_model_paper_regime():
    """Paper Fig. 7 regime: N nodes × G GPUs, 1 NIC — hierarchical wins
    and the advantage grows with node count (1.66× at 4×8 → 2× at 8×8)."""
    B = 16e6                                      # 16 MB per device (paper)
    s4 = alltoall.cost_flat(B, 4, 8, alltoall.PCIE, alltoall.ETH100) / \
        alltoall.cost_hierarchical(B, 4, 8, alltoall.PCIE, alltoall.ETH100)
    s8 = alltoall.cost_flat(B, 8, 8, alltoall.PCIE, alltoall.ETH100) / \
        alltoall.cost_hierarchical(B, 8, 8, alltoall.PCIE, alltoall.ETH100)
    assert 1.2 < s4 < 3.0, s4       # paper: 1.66× at 4×8
    assert s4 < s8 < 4.0, (s4, s8)  # paper: 2× at 8×8 — grows with N


def test_cost_model_message_aggregation():
    """The mechanism: G× fewer inter-node messages, G× larger each,
    identical NIC bytes — the win is pure per-message overhead."""
    B, N, G = 16e6, 8, 8
    M = N * G
    # message counts through one NIC
    assert G * (N - 1) == G * G * (N - 1) / G
    # message sizes: B/(G·N) flat → B/N hier (paper: G² aggregation of
    # the per-GPU-pair chunks into per-node bundles)
    assert (B / N) / (B / M) == G
    # NIC bytes identical
    flat_bytes = G * (M - G) / M * B
    hier_bytes = G * (N - 1) / N * B
    assert abs(flat_bytes - hier_bytes) < 1e-6


def test_hierarchical_inner_must_divide_axis(mesh_model8):
    """Bad config fails loudly at trace time (no silent flat fallback,
    no opaque reshape assert inside shard_map)."""
    x = jax.random.normal(RNG, (64, 4, 8))
    with pytest.raises(ValueError, match="a2a_inner"):
        _run(mesh_model8, lambda v: alltoall.all_to_all(
            v, "model", mode="hierarchical", inner=3))(x)
    with pytest.raises(ValueError, match="outer"):
        _run(mesh_model8, lambda v: alltoall.all_to_all(
            v, "model", mode="hierarchical", inner=2, outer=3))(x)


def test_unknown_a2a_mode_rejected():
    """A typo'd mode must raise naming A2A_MODES whatever ``inner`` is:
    with inner<=1 it used to silently run flat, with inner>1 it died on
    a bare ``assert`` stripped under ``python -O``."""
    x = jax.random.normal(RNG, (8, 4, 8))
    for inner in (1, 2):
        with pytest.raises(ValueError, match="'flat', 'hierarchical'"):
            alltoall.all_to_all(x, "model", mode="ring", inner=inner)


def test_bad_a2a_inner_rejected_by_config():
    from repro.core.config import MoEConfig
    with pytest.raises(ValueError, match="a2a_inner"):
        MoEConfig(num_experts=8, a2a_inner=0)
    with pytest.raises(ValueError, match="grouped_ep_bound_factor"):
        MoEConfig(num_experts=8, grouped_ep_bound_factor=0.0)


def test_bad_a2a_inner_rejected_by_moe_layer(mesh_model8):
    """The MoE entry point names the config fields before tracing."""
    from repro.core import moe
    from repro.core.config import MoEConfig
    cfg = MoEConfig(num_experts=8, gate="switch", a2a="hierarchical",
                    a2a_inner=3)
    p = moe.init_moe_params(RNG, cfg, 16, 32, 8, act="swiglu",
                            dtype=jnp.float32)
    x = jax.random.normal(RNG, (8, 4, 16))
    with pytest.raises(ValueError, match="a2a_inner"):
        moe.sharded_moe_apply(mesh_model8, cfg, p, x, num_experts=8,
                              act="swiglu")


# ---------------------------------------------------------------------------
# grouped exchange (dropless EP): counts + bounded segments
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode,inner", [("flat", 1), ("hierarchical", 2),
                                        ("hierarchical", 4)])
def test_grouped_a2a_counts_and_tokens_land_source_major(mesh_model8, mode,
                                                         inner):
    """recv chunk s on rank r == send chunk r on rank s, for both the
    token payload and the count matrix, in every a2a mode."""
    M, B, d, E_local = 8, 4, 16, 2
    x = jax.random.normal(RNG, (M * M, B, d))          # per-device (M, B, d)
    counts = jnp.arange(M * M * E_local, dtype=jnp.int32).reshape(
        M * M, E_local)

    def fn(v, c):
        return alltoall.grouped_all_to_all(v, c, "model", mode=mode,
                                           inner=inner)

    recv_x, recv_c = jax.jit(shard_map(
        fn, mesh=mesh_model8, in_specs=(P("model"), P("model")),
        out_specs=(P("model"), P("model")), check_vma=False))(x, counts)
    # global views: sender s's chunk for dest r is x[s*M + r]
    rx = np.asarray(recv_x).reshape(M, M, B, d)        # [rank, src, ...]
    rc = np.asarray(recv_c).reshape(M, M, E_local)
    sx = np.asarray(x).reshape(M, M, B, d)             # [rank, dest, ...]
    sc = np.asarray(counts).reshape(M, M, E_local)
    for r in range(M):
        for s in range(M):
            np.testing.assert_array_equal(rx[r, s], sx[s, r])
            np.testing.assert_array_equal(rc[r, s], sc[s, r])


def test_grouped_a2a_gradient(mesh_model8):
    x = jax.random.normal(RNG, (64, 4, 8))
    counts = jnp.ones((64, 2), jnp.int32)

    def loss(v):
        out, _ = shard_map(
            lambda u, c: alltoall.grouped_all_to_all(
                u, c, "model", mode="hierarchical", inner=4),
            mesh=mesh_model8, in_specs=(P("model"), P("model")),
            out_specs=(P("model"), P("model")), check_vma=False)(v, counts)
        return jnp.sum(out ** 2)

    g = jax.jit(jax.grad(loss))(x)
    np.testing.assert_allclose(np.asarray(g), 2 * np.asarray(x), rtol=1e-6)


# ---------------------------------------------------------------------------
# a2a_inner validation (bugfix: inner < 1 silently ran the flat path,
# disabling the paper's hierarchical win with no signal)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("inner", [0, -1])
def test_inner_below_one_raises_naming_the_config_field(inner):
    x = jnp.zeros((8, 4, 8))
    with pytest.raises(ValueError, match="a2a_inner"):
        alltoall.all_to_all(x, "model", mode="hierarchical", inner=inner)
    with pytest.raises(ValueError, match="a2a_inner"):
        alltoall.all_to_all(x, "model", mode="flat", inner=inner)


def test_inner_one_is_the_documented_degenerate_flat_case(mesh_model8):
    x = jax.random.normal(RNG, (64, 4, 16))
    flat = _run(mesh_model8, lambda v: alltoall.flat_all_to_all(v, "model"))
    deg = _run(mesh_model8, lambda v: alltoall.all_to_all(
        v, "model", mode="hierarchical", inner=1))
    np.testing.assert_array_equal(np.asarray(flat(x)), np.asarray(deg(x)))


# ---------------------------------------------------------------------------
# quantized exchange (payload_dtype): wire dtype, scales, round trips
# ---------------------------------------------------------------------------

# |dequant(quantize(x)) - x| <= tol · chunk_amax — grid-step bounds:
# int8 rounds to a 1/127 grid (half-step 0.004); float8_e4m3fn carries
# 3 mantissa bits (rel. step 2^-3, half-step ~6% of the element);
# float8_e5m2 carries 2 (half-step ~12.5%).
QUANT_TOLS = {
    "int8": 0.005,
    "float8_e4m3fn": 0.07,
    "float8_e5m2": 0.15,
}


@pytest.mark.parametrize("qdt", sorted(alltoall.PAYLOAD_QMAX))
def test_quantize_payload_round_trip_within_grid_step(qdt):
    chunk_mag = jnp.array([0.1, 1.0, 10.0, 100.0])    # scale-varied chunks
    x = jax.random.normal(RNG, (4, 16, 32)) * chunk_mag[:, None, None]
    q, s = alltoall.quantize_payload(x, qdt)
    assert q.dtype == jnp.dtype(qdt)
    assert s.shape == (4,) and s.dtype == jnp.float32
    y = np.asarray(alltoall.dequantize_payload(q, s, jnp.float32))
    xf = np.asarray(x, np.float32)
    amax = np.max(np.abs(xf), axis=(1, 2), keepdims=True)
    assert np.all(np.abs(y - xf) <= QUANT_TOLS[qdt] * amax)


def test_quantize_payload_zero_chunk_round_trips_exactly():
    q, s = alltoall.quantize_payload(jnp.zeros((2, 8, 4)), "int8")
    np.testing.assert_array_equal(np.asarray(s), 1.0)
    np.testing.assert_array_equal(
        np.asarray(alltoall.dequantize_payload(q, s, jnp.float32)), 0.0)


def test_unknown_payload_dtype_raises():
    with pytest.raises(ValueError, match="payload"):
        alltoall.quantize_payload(jnp.zeros((2, 4, 4)), "int4")


@pytest.mark.parametrize("mode,inner", [("flat", 1), ("hierarchical", 2)])
@pytest.mark.parametrize("qdt", ["int8", "float8_e4m3fn"])
def test_quantized_exchange_matches_unquantized(mesh_model8, qdt, mode,
                                                inner):
    """Same chunk permutation as grouped_all_to_all; counts cross EXACTLY
    (the scales ride as a bitcast int32 column of the count exchange);
    tokens agree within the per-chunk grid step."""
    M, B, d, E_local = 8, 4, 16, 2
    x = jax.random.normal(RNG, (M * M, B, d))
    counts = jnp.arange(M * M * E_local, dtype=jnp.int32).reshape(
        M * M, E_local)

    def run(f):
        return jax.jit(shard_map(
            f, mesh=mesh_model8, in_specs=(P("model"), P("model")),
            out_specs=(P("model"), P("model")), check_vma=False))(x, counts)

    rx, rc = run(lambda v, c: alltoall.grouped_all_to_all(
        v, c, "model", mode=mode, inner=inner))
    qx, qc = run(lambda v, c: alltoall.quantized_exchange(
        v, c, "model", mode=mode, inner=inner, payload_dtype=qdt))
    np.testing.assert_array_equal(np.asarray(rc), np.asarray(qc))
    assert qx.dtype == x.dtype                 # dequantized on arrival
    rxf = np.asarray(rx, np.float32)
    amax = np.max(np.abs(rxf), axis=(1, 2), keepdims=True)
    assert np.all(np.abs(np.asarray(qx, np.float32) - rxf)
                  <= QUANT_TOLS[qdt] * amax)


def test_quantized_exchange_combine_direction_returns_f32(mesh_model8):
    """counts=None (combine direction): scales go over their own tiny
    flat exchange and the result lands in f32 so the combine reduction
    accumulates at full precision."""
    x = jax.random.normal(RNG, (64, 4, 8), dtype=jnp.bfloat16)

    def fn(v):
        out, rc = alltoall.quantized_exchange(
            v, None, "model", payload_dtype="int8", out_dtype=jnp.float32)
        assert rc is None
        return out

    out = _run(mesh_model8, fn)(x)
    assert out.dtype == jnp.float32
    ref = np.asarray(_run(mesh_model8, lambda v: alltoall.flat_all_to_all(
        v, "model"))(x), np.float32)
    amax = np.max(np.abs(ref), axis=(1, 2), keepdims=True)
    assert np.all(np.abs(np.asarray(out) - ref) <= 0.01 * amax)


def test_quantized_exchange_gradient_is_quantized_involution(mesh_model8):
    """d/dx sum(a2a(x)^2) = 2x for a permutation; the quantized VJP
    sends the cotangent through the SAME low-precision wire, so the
    gradient matches to two grid steps — and never recomputes the
    forward (the residuals carry only the count matrix)."""
    x = jax.random.normal(RNG, (64, 4, 8))
    counts = jnp.ones((64, 2), jnp.int32)

    def loss(v):
        out, _ = shard_map(
            lambda u, c: alltoall.quantized_exchange(
                u, c, "model", mode="hierarchical", inner=4,
                payload_dtype="int8"),
            mesh=mesh_model8, in_specs=(P("model"), P("model")),
            out_specs=(P("model"), P("model")), check_vma=False)(v, counts)
        return jnp.sum(out.astype(jnp.float32) ** 2)

    g = np.asarray(jax.jit(jax.grad(loss))(x))
    ref = 2 * np.asarray(x)
    assert np.abs(g - ref).max() <= 0.03 * np.abs(ref).max()
