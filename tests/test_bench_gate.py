"""The perf gate itself is covered: ``benchmarks/run.py --check`` must
exit nonzero on an injected regression and zero on a clean rerun.

Runs the real harness in subprocesses against a SCRATCH json (the
``--json`` flag), never the committed BENCH_moe.json.  The ``alltoall``
suite is the vehicle: six of its eight entries are α–β cost-MODEL
outputs — deterministic, ≥ 1 ms (so they clear the gate's noise floor),
and exactly reproducible run-to-run — which makes both directions of
the test flake-free: the clean check's drift median sits at 1.0, and an
injected 4× regression on a model entry survives the harness's
best-of-2 remeasure by construction.

Slow-marked (four benchmark-suite subprocess runs); select with
``-m slow``.
"""
import json
import os
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]
INJECT_ENTRY = "a2a/model/gpu-16x8"        # deterministic cost-model entry


def _run(tmp_json, *extra, suite="alltoall"):
    cmd = [sys.executable, "-m", "benchmarks.run", "--only", suite,
           "--json", str(tmp_json), *extra]
    env = {**os.environ, "PYTHONPATH": "src",
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8"}
    return subprocess.run(cmd, cwd=REPO, env=env, capture_output=True,
                          text=True, timeout=900)


@pytest.mark.slow
def test_check_gate_exit_codes(tmp_path):
    tmp_json = tmp_path / "bench.json"

    # --check against a missing baseline is a setup error, caught before
    # any benchmarking burns minutes
    r = _run(tmp_json, "--check")
    assert r.returncode == 1, r.stdout + r.stderr
    assert "no" in r.stdout and "diff against" in r.stdout

    # plain run commits the baseline
    r = _run(tmp_json)
    assert r.returncode == 0, r.stdout + r.stderr
    entries = json.loads(tmp_json.read_text())["entries"]
    assert INJECT_ENTRY in entries
    assert entries[INJECT_ENTRY]["us"] >= 1000.0   # clears the noise floor

    # clean rerun: cost-model entries reproduce exactly, drift ≈ 1, no
    # regression (factor 1.6 per run.py's own guidance for this box)
    r = _run(tmp_json, "--check", "--check-factor", "1.6")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "--check ok" in r.stdout

    # inject a 4x apparent regression into ONE gated entry (committed
    # time quartered; the fresh run still reports the same model value)
    committed = json.loads(tmp_json.read_text())
    committed["entries"][INJECT_ENTRY]["us"] /= 4.0
    assert committed["entries"][INJECT_ENTRY]["us"] >= 1000.0  # still gated
    tmp_json.write_text(json.dumps(committed))

    r = _run(tmp_json, "--check", "--check-factor", "1.6")
    assert r.returncode == 1, r.stdout + r.stderr
    assert "REGRESSION" in r.stdout and INJECT_ENTRY in r.stdout
    # the harness remeasured once (best-of-2) before failing
    assert "remeasuring" in r.stdout


def test_unknown_suite_is_an_error(tmp_path):
    """--only with a typo'd suite name fails fast (argparse error naming
    the available suites) instead of silently benchmarking nothing."""
    r = _run(tmp_path / "bench.json", suite="decod")
    assert r.returncode == 2, r.stdout + r.stderr
    assert "unknown suite" in r.stderr and "decode" in r.stderr


@pytest.mark.slow
def test_decode_suite_registered_and_survives_check(tmp_path):
    """The serving decode suite is a first-class citizen of the perf
    gate: a plain run commits `decode/*` entries (tagged with the suite
    name, sort-vs-grouped ratio recorded), and a --check rerun against
    that baseline passes."""
    tmp_json = tmp_path / "bench.json"
    r = _run(tmp_json, suite="decode")
    assert r.returncode == 0, r.stdout + r.stderr
    entries = json.loads(tmp_json.read_text())["entries"]
    for name in ("decode/step/sort", "decode/step/grouped",
                 "decode/ar/grouped"):
        assert name in entries, sorted(entries)
        assert entries[name]["suite"] == "decode"
    assert entries["decode/step/grouped"]["grouped_vs_sort"] > 0
    assert entries["decode/ar/grouped"]["ar_tokens_per_s"] > 0

    r = _run(tmp_json, "--check", "--check-factor", "1.6", suite="decode")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "--check ok" in r.stdout
