"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import hypothesis, st

from repro.kernels import ops, ref
from repro.kernels.layout_transform import gather_rows
from repro.kernels.topk_gate import fused_topk_gate


@hypothesis.given(S=st.integers(1, 300), E=st.sampled_from([4, 16, 64, 128]),
                  k=st.sampled_from([1, 2, 4]), seed=st.integers(0, 2**30),
                  dtype=st.sampled_from(["float32", "bfloat16"]))
@hypothesis.settings(max_examples=25, deadline=None)
def test_topk_kernel_sweep(S, E, k, seed, dtype):
    logits = jax.random.normal(jax.random.PRNGKey(seed), (S, E),
                               jnp.dtype(dtype))
    v, i, m, z = fused_topk_gate(logits, k, interpret=True)
    rv, ri, rm, rz = ref.ref_topk_gate(logits, k)
    np.testing.assert_allclose(np.asarray(v), np.asarray(rv), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))
    np.testing.assert_allclose(np.asarray(m), np.asarray(rm), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(z), np.asarray(rz), rtol=1e-4)


def test_topk_kernel_ties_break_low_index():
    logits = jnp.array([[1.0, 3.0, 3.0, 0.0]])
    _, i, _, _ = fused_topk_gate(logits, 2, interpret=True)
    np.testing.assert_array_equal(np.asarray(i), [[1, 2]])


@hypothesis.given(N=st.integers(1, 64), M=st.integers(1, 64),
                  d=st.sampled_from([8, 128, 256]), seed=st.integers(0, 2**30),
                  dtype=st.sampled_from(["float32", "bfloat16"]))
@hypothesis.settings(max_examples=20, deadline=None)
def test_gather_kernel_sweep(N, M, d, seed, dtype):
    key = jax.random.PRNGKey(seed)
    src = jax.random.normal(key, (N, d), jnp.dtype(dtype))
    idx = jax.random.randint(key, (M,), -2, N)
    out = gather_rows(src, idx, True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.ref_gather_rows(src, idx)),
                               rtol=1e-6)


def test_gather_kernel_vjp_is_scatter_add():
    src = jax.random.normal(jax.random.PRNGKey(0), (8, 16))
    idx = jnp.array([0, 0, 3, -1, 7])

    def f(s):
        return jnp.sum(gather_rows(s, idx, True) ** 2)

    g = jax.grad(f)(src)
    # rows 0 hit twice, 3 and 7 once, others zero
    expect = np.zeros((8, 16), np.float32)
    out = np.asarray(ref.ref_gather_rows(src, idx))
    for j, i in enumerate([0, 0, 3, -1, 7]):
        if i >= 0:
            expect[i] += 2 * out[j]
    np.testing.assert_allclose(np.asarray(g), expect, rtol=1e-5)


def test_ops_layout_roundtrip_vs_core():
    from repro.core import capacity, gating, layout
    from repro.core.config import MoEConfig
    cfg = MoEConfig(num_experts=8, gate="topk", top_k=2, capacity_factor=1.0)
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (64, 128))
    g = gating.route(cfg, jax.random.normal(key, (64, 8)))
    C = capacity.expert_capacity(cfg, 64, 8)
    plan = layout.plan_sort(g, 8, C)
    b_ref = layout.dispatch_scatter(x, plan, 8, C)
    b_ker = ops.layout_dispatch(x, plan.slot, 8, C)
    np.testing.assert_allclose(np.asarray(b_ref), np.asarray(b_ker), rtol=1e-6)
    y_ref = layout.combine_gather(b_ref, plan)
    y_ker = ops.layout_combine(b_ref, plan.slot, plan.weight)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_ker),
                               rtol=1e-4, atol=1e-6)


def test_topk_softmax_weights_consistency():
    logits = jax.random.normal(jax.random.PRNGKey(7), (32, 16))
    idx, w, probs = ops.topk_softmax_weights(logits, 2)
    full = np.asarray(jax.nn.softmax(logits, -1))
    np.testing.assert_allclose(np.asarray(probs), full, rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(w), np.take_along_axis(full, np.asarray(idx), 1), rtol=1e-5)
