"""Layout transform (paper Fig. 4): sort path ≡ dense path, capacity/drop
semantics, round-trip."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import hypothesis, st

from repro.core import capacity, gating, layout
from repro.core.config import MoEConfig

RNG = jax.random.PRNGKey(1)


@hypothesis.given(S=st.integers(4, 128), E=st.sampled_from([2, 4, 8, 16]),
                  k=st.integers(1, 3), cf=st.sampled_from([0.5, 1.0, 2.0]),
                  seed=st.integers(0, 2**30))
@hypothesis.settings(max_examples=25, deadline=None)
def test_plan_sort_equals_plan_cumsum(S, E, k, cf, seed):
    k = min(k, E)
    cfg = MoEConfig(num_experts=E, gate="topk", top_k=k, capacity_factor=cf)
    logits = jax.random.normal(jax.random.PRNGKey(seed), (S, E))
    g = gating.route(cfg, logits)
    C = capacity.expert_capacity(cfg, S, E)
    p1 = layout.plan_sort(g, E, C)
    p2 = layout.plan_cumsum(g, E, C)
    np.testing.assert_array_equal(np.asarray(p1.slot), np.asarray(p2.slot))
    np.testing.assert_allclose(np.asarray(p1.weight), np.asarray(p2.weight),
                               rtol=1e-6)


@hypothesis.given(S=st.integers(8, 64), d=st.sampled_from([8, 32]),
                  seed=st.integers(0, 2**30))
@hypothesis.settings(max_examples=15, deadline=None)
def test_dispatch_scatter_equals_dense(S, d, seed):
    E, k = 8, 2
    cfg = MoEConfig(num_experts=E, gate="topk", top_k=k, capacity_factor=1.0)
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (S, d))
    g = gating.route(cfg, jax.random.normal(key, (S, E)))
    C = capacity.expert_capacity(cfg, S, E)
    plan = layout.plan_sort(g, E, C)
    b1 = layout.dispatch_scatter(x, plan, E, C)
    b2 = layout.dispatch_dense(x, plan, E, C)
    np.testing.assert_allclose(np.asarray(b1), np.asarray(b2),
                               rtol=1e-5, atol=1e-6)
    y1 = layout.combine_gather(b1, plan)
    y2 = layout.combine_dense(b1, plan, E, C)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-6)


def test_no_drops_when_capacity_ample():
    S, E = 64, 8
    cfg = MoEConfig(num_experts=E, gate="switch", capacity_factor=8.0)
    g = gating.route(cfg, jax.random.normal(RNG, (S, E)))
    C = capacity.expert_capacity(cfg, S, E)
    plan = layout.plan_sort(g, E, C)
    assert int(jnp.sum(plan.slot < 0)) == 0


def test_roundtrip_identity_weights_one():
    """dispatch → combine with weight 1 and no drops reproduces tokens."""
    S, E, d = 32, 4, 16
    cfg = MoEConfig(num_experts=E, gate="switch", capacity_factor=8.0)
    x = jax.random.normal(RNG, (S, d))
    g = gating.route(cfg, jax.random.normal(RNG, (S, E)))
    g = g._replace(combine_weights=jnp.ones_like(g.combine_weights))
    C = capacity.expert_capacity(cfg, S, E)
    plan = layout.plan_sort(g, E, C)
    buf = layout.dispatch_scatter(x, plan, E, C)
    y = layout.combine_gather(buf, plan)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=1e-6)


def test_priority_is_slot_major():
    """All first-choice assignments outrank any second choice (GShard)."""
    S, E, C = 4, 2, 2
    # every token picks expert 0 first, expert 1 second
    g = gating.GateOutput(
        expert_index=jnp.array([[0, 1]] * S, jnp.int32),
        combine_weights=jnp.ones((S, 2)) * 0.5,
        router_probs=jnp.ones((S, E)) / E,
        logits=jnp.zeros((S, E)))
    plan = layout.plan_sort(g, E, C)
    slots = np.asarray(plan.slot)
    # tokens 0,1 keep slot-0 choices; tokens 2,3 dropped on expert 0
    assert (slots[:2, 0] >= 0).all() and (slots[2:, 0] < 0).all()
    # expert 1 receives tokens 0,1's SECOND choices (capacity 2)
    assert (slots[:2, 1] >= 0).all() and (slots[2:, 1] < 0).all()


def test_dropped_token_passes_through_residual():
    """Capacity-dropped tokens contribute 0 (residual carries them)."""
    S, E, d = 16, 2, 8
    cfg = MoEConfig(num_experts=E, gate="switch", capacity_factor=0.1)
    x = jax.random.normal(RNG, (S, d))
    g = gating.route(cfg, jax.random.normal(RNG, (S, E)))
    C = capacity.expert_capacity(cfg, S, E)
    plan = layout.plan_sort(g, E, C)
    dropped = np.asarray(plan.slot[:, 0]) < 0
    assert dropped.any()
    buf = layout.dispatch_scatter(x, plan, E, C)
    y = layout.combine_gather(buf, plan)
    assert np.allclose(np.asarray(y)[dropped], 0.0)


def test_expert_capacity_aligned_for_tiny_decode_batch():
    """Regression: the total-assignment clamp must not break the align-8
    contract (T=4, K=1 used to return 4 — an unaligned (E, C, d) buffer
    for the Pallas layout kernel)."""
    cfg = MoEConfig(num_experts=8, gate="switch")
    for T in (1, 2, 3, 4, 7):
        C = capacity.expert_capacity(cfg, T, 8)
        assert C % 8 == 0, (T, C)
        assert C >= T            # clamp still bounds away from E·cf blowup
    cfg2 = MoEConfig(num_experts=8, gate="topk", top_k=2,
                     capacity_factor=64.0)
    C = capacity.expert_capacity(cfg2, 4, 8)
    assert C % 8 == 0 and C <= 8        # ceil(4·2/8)·8


def test_grouped_segment_bound_static_and_aligned():
    cfg = MoEConfig(num_experts=8, gate="topk", top_k=2)
    # default: fully dropless — a rank can receive every assignment
    assert capacity.grouped_segment_bound(cfg, 64, 4) == 128
    # factor: balanced share × headroom, aligned, clamped at dropless
    cfg_f = MoEConfig(num_experts=8, gate="topk", top_k=2,
                      grouped_ep_bound_factor=1.5)
    b = capacity.grouped_segment_bound(cfg_f, 64, 4)
    assert b == 48 and b % 8 == 0       # ceil(128/4 · 1.5) = 48
    big = MoEConfig(num_experts=8, gate="topk", top_k=2,
                    grouped_ep_bound_factor=100.0)
    assert capacity.grouped_segment_bound(big, 64, 4) == 128
    # unaligned totals round the clamp up, preserving alignment
    assert capacity.grouped_segment_bound(cfg, 3, 4) % 8 == 0
