"""Optional-``hypothesis`` shim.

The container may not ship ``hypothesis``; property tests should SKIP in
that case while the plain pytest tests in the same modules still run.
Test modules import ``hypothesis``/``st`` from here instead of directly:

    from hypothesis_compat import hypothesis, st

When the real package is present this is a pure re-export.  When it is
absent, ``@hypothesis.given(...)`` swallows the original test and returns
a zero-argument stand-in that calls ``pytest.skip`` (a plain skip mark
would leave the strategy parameters looking like unresolvable fixtures).
"""
try:
    import hypothesis
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _Strategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    class _Hypothesis:
        @staticmethod
        def given(*a, **k):
            def deco(fn):
                def skipped():
                    pytest.skip("hypothesis not installed")
                skipped.__name__ = fn.__name__
                skipped.__doc__ = fn.__doc__
                return skipped
            return deco

        @staticmethod
        def settings(*a, **k):
            return lambda fn: fn

    st = _Strategies()
    hypothesis = _Hypothesis()
