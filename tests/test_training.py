"""Training substrate: loss falls, grad-accum equivalence, CE chunking,
optimizer math, checkpoint roundtrip."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.config import TrainConfig
from repro.data import SyntheticLM
from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.models import transformer as T
from repro.optim import adamw_update, clip_by_global_norm, init_opt_state, make_schedule
from repro.training import chunked_ce_loss, make_train_step
from repro.training.train_step import init_train_state

RNG = jax.random.PRNGKey(0)


def test_loss_decreases_moe(mesh1):
    cfg = configs.smoke_config("dbrx-132b")
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=5, total_steps=25)
    state = init_train_state(RNG, cfg, tcfg)
    ds = SyntheticLM(cfg, batch=8, seq_len=32)
    step = jax.jit(make_train_step(cfg, tcfg, mesh1), donate_argnums=(0,))
    losses = []
    for s in range(25):
        state, m = step(state, ds.next_batch(s), jax.random.fold_in(RNG, s))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])


def test_grad_accum_equivalence(mesh1):
    """microbatches=2 produces the same update as microbatches=1."""
    cfg = configs.smoke_config("starcoder2-3b").replace(dtype="float32")
    t1 = TrainConfig(total_steps=2, warmup_steps=0, microbatches=1)
    t2 = TrainConfig(total_steps=2, warmup_steps=0, microbatches=2)
    s0 = init_train_state(RNG, cfg, t1)
    ds = SyntheticLM(cfg, batch=4, seq_len=16)
    b = ds.next_batch(0)
    s1, m1 = jax.jit(make_train_step(cfg, t1, mesh1))(s0, b, RNG)
    s2, m2 = jax.jit(make_train_step(cfg, t2, mesh1))(s0, b, RNG)
    np.testing.assert_allclose(float(m1["ce"]), float(m2["ce"]), rtol=1e-5)
    a = jax.tree.leaves(s1.params)
    c = jax.tree.leaves(s2.params)
    for x, y in zip(a, c):
        # loose rtol/atol: the two microbatch schedules sum gradients in
        # a different order; f32 accumulation noise leaves O(1/65536)
        # elements past rtol=1e-3 (observed max abs diff ~8e-6 on values
        # ~5e-3) — not a bug, so don't chase bit-exactness.
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=2e-3, atol=1e-5)


def test_chunked_ce_equals_full(mesh1):
    cfg = configs.smoke_config("yi-6b")
    p = T.init_model(RNG, cfg)
    B, S = 2, 32
    h = jax.random.normal(RNG, (B, S, cfg.d_model), jnp.float32)
    t = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
    m = jnp.ones((B, S))
    for nc in (1, 2, 8):
        li = float(chunked_ce_loss(p, cfg, h, t, m, mesh1, num_chunks=nc))
        if nc == 1:
            base = li
        else:
            np.testing.assert_allclose(li, base, rtol=1e-5)


def test_adamw_against_reference():
    """One AdamW step vs a hand-rolled numpy reference."""
    tcfg = TrainConfig(learning_rate=1e-2, weight_decay=0.1)
    p = {"w": jnp.array([1.0, -2.0, 3.0])}
    g = {"w": jnp.array([0.1, 0.2, -0.3])}
    st = init_opt_state(p, tcfg)
    newp, newst = adamw_update(g, st, p, tcfg, jnp.asarray(1e-2))
    m = 0.1 * np.asarray(g["w"])
    v = 0.05 * np.asarray(g["w"]) ** 2
    mh, vh = m / 0.1, v / 0.05
    ref = np.asarray(p["w"]) - 1e-2 * (mh / (np.sqrt(vh) + tcfg.eps)
                                       + 0.1 * np.asarray(p["w"]))
    np.testing.assert_allclose(np.asarray(newp["w"]), ref, rtol=1e-5)


def test_grad_clip():
    g = {"a": jnp.ones((4,)) * 10.0}
    clipped, gn = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(gn), 20.0, rtol=1e-5)
    np.testing.assert_allclose(
        float(jnp.linalg.norm(clipped["a"])), 1.0, rtol=1e-4)


def test_schedule_warmup_and_decay():
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
    sched = make_schedule(tcfg)
    assert float(sched(jnp.asarray(0))) == 0.0
    np.testing.assert_allclose(float(sched(jnp.asarray(10))), 1e-3, rtol=1e-3)
    assert float(sched(jnp.asarray(100))) < 1e-5


def test_checkpoint_roundtrip(mesh1):
    cfg = configs.smoke_config("rwkv6-1.6b")
    tcfg = TrainConfig()
    state = init_train_state(RNG, cfg, tcfg)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, state, 7)
        state2, step = restore_checkpoint(d, state)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state.params),
                    jax.tree.leaves(state2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bf16_moments_mode():
    tcfg = TrainConfig(optimizer_state_dtype="bfloat16")
    p = {"w": jnp.ones((8, 8))}
    st = init_opt_state(p, tcfg)
    assert st["m"]["w"].dtype == jnp.bfloat16
    newp, newst = adamw_update({"w": jnp.ones((8, 8)) * 0.1}, st, p, tcfg,
                               jnp.asarray(1e-3))
    assert newst["v"]["w"].dtype == jnp.bfloat16
    assert bool(jnp.all(jnp.isfinite(newp["w"])))
