"""Dense-to-Sparse annealing schedule + continuous-batching scheduler."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as T
from repro.serving.engine import generate
from repro.serving.scheduler import Request, SlotServer
from repro.training.anneal import d2s_temperature, with_temperature


def test_d2s_schedule_monotone_and_quantized():
    ts = [d2s_temperature(s, t_start=2.0, t_min=0.05, decay_steps=100,
                          levels=8) for s in range(0, 120, 5)]
    assert ts[0] == pytest.approx(2.0, rel=1e-6)
    assert ts[-1] == pytest.approx(0.05, rel=1e-6)
    assert all(a >= b - 1e-9 for a, b in zip(ts, ts[1:]))
    assert len(set(round(t, 6) for t in ts)) <= 8   # bounded retraces


def test_d2s_annealed_training_goes_sparse(mesh1):
    """Route the same logits at schedule start vs end: slot-0 mass grows."""
    import dataclasses
    from repro.core import gating
    from repro.core.config import MoEConfig
    base = MoEConfig(num_experts=8, gate="dense_to_sparse", top_k=4)
    logits = jax.random.normal(jax.random.PRNGKey(0), (256, 8))
    masses = []
    for step in (0, 1000):
        t = d2s_temperature(step, decay_steps=1000)
        cfg = dataclasses.replace(base, gumbel_temperature=t)
        out = gating.route(cfg, logits)
        masses.append(float(jnp.mean(out.combine_weights[:, 0])))
    assert masses[0] < 0.5 < masses[1]


def test_with_temperature_requires_d2s():
    cfg = configs.get_config("dbrx-132b")
    with pytest.raises(AssertionError):
        with_temperature(cfg, 0.5)


def test_slot_server_matches_generate(mesh1):
    """Continuous batching reproduces the plain generate() outputs."""
    cfg = configs.smoke_config("starcoder2-3b").replace(dtype="float32")
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    rng = jax.random.PRNGKey(1)
    prompts = [jax.random.randint(jax.random.fold_in(rng, i), (6,), 0,
                                  cfg.vocab_size) for i in range(3)]
    gen = 5
    # reference: one-at-a-time greedy generate
    refs = [np.asarray(generate(params, cfg, p[None, :], steps=gen,
                                mesh=mesh1))[0, 6:] for p in prompts]
    # continuous batching with a pool SMALLER than the request count
    srv = SlotServer(cfg, params, slots=2, cache_len=6 + gen + 2, mesh=mesh1)
    reqs = [Request(uid=i, prompt=p, max_new=gen)
            for i, p in enumerate(prompts)]
    done = srv.run(reqs)
    assert len(done) == 3 and all(r.done for r in done)
    for r in done:
        np.testing.assert_array_equal(np.asarray(r.out), refs[r.uid])
