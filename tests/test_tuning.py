"""Auto-tuned dispatch plans (``core/tuning.py``).

Covers the PR 9 contract: the resolver is a deterministic pure
function of ``(cfg, mesh factoring, static token count, dtype,
fabric)``; its a2a decision follows the α–β cost model (hierarchical
wins the small/medium-payload regime — the paper's message-aggregation
win — and the flat/hierarchical crossover payload grows with the slow
link's latency); every ``overlap_chunks`` it emits divides the grouped
segment bound; calibration round-trips through ``TUNE_moe.json`` with
a corrupt-file fallback to the static table; the shipped MoE presets'
``"auto"`` knobs resolve to configs the validators accept on the
meshes their docstrings claim; and serving with ``"auto"`` knobs hits
the compiled-step cache exactly as often as explicit ints
(``engine.trace_counts``), with validator errors naming the RESOLVED
values.
"""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro import configs
from repro.core import capacity, moe, tuning
from repro.core.alltoall import FABRICS, LinkSpec
from repro.core.config import AUTO, MoEConfig
from repro.launch import mesh as mesh_lib
from repro.models import transformer as T
from repro.serving import engine, generate

RNG = jax.random.PRNGKey(9)


@pytest.fixture(autouse=True)
def _restore_tuning_state():
    """Every test runs against (and restores) the process defaults —
    the tuner's mode/fabric are process globals set by the launchers."""
    prev = tuning.get_tuning()
    yield
    tuning.set_tuning(mode=prev[0], fabric=prev[1])
    tuning.clear_plan_cache()


def _auto_cfg(**kw):
    kw.setdefault("num_experts", 16)
    kw.setdefault("gate", "switch")
    kw.setdefault("capacity_factor", 1.25)
    kw.setdefault("dispatch", "grouped")
    return MoEConfig(a2a="auto", overlap_chunks="auto",
                     grouped_block_m="auto",
                     grouped_ep_bound_factor="auto", **kw)


# ---------------------------------------------------------------------------
# the resolver: determinism + the cost-model decision surface
# ---------------------------------------------------------------------------

def test_resolver_is_deterministic():
    cfg = _auto_cfg()
    kw = dict(model_size=4, tokens_per_shard=128, d_model=256,
              dtype="float32", fabric="ici_dcn")
    p1 = tuning.resolve_plan(cfg, **kw)
    p2 = tuning.resolve_plan(cfg, **kw)
    assert p1 is p2                       # cached cell
    tuning.clear_plan_cache()
    p3 = tuning.resolve_plan(cfg, **kw)   # recomputed from scratch
    assert p1 == p3


def test_explicit_config_is_passed_through_unchanged():
    cfg = MoEConfig(num_experts=16, gate="switch", capacity_factor=1.25,
                    dispatch="grouped", a2a="flat", overlap_chunks=2)
    assert not tuning.has_auto_knobs(cfg)
    out = tuning.resolve_moe_config(cfg, model_size=4, tokens_per_shard=64,
                                    d_model=128)
    assert out is cfg                     # same object, not a copy


def test_small_payload_resolves_hierarchical_large_resolves_flat():
    """The model's decision surface (paper Fig. 7): message aggregation
    wins while per-message latency dominates; at large payloads the
    hierarchical path's extra fast-dim hop loses to flat."""
    small = tuning.resolve_plan(_auto_cfg(), model_size=4,
                                tokens_per_shard=16, d_model=32,
                                dtype="float32", fabric="ici_dcn")
    assert small.a2a == "hierarchical" and small.a2a_inner == 2
    large = tuning.resolve_plan(_auto_cfg(), model_size=4,
                                tokens_per_shard=4096, d_model=4096,
                                dtype="float32", fabric="ici_dcn")
    assert large.a2a == "flat" and large.a2a_inner == 1
    assert large.payload_bytes > small.payload_bytes


def _flat_crossover_T(slow_alpha: float) -> int:
    """Smallest tokens_per_shard (powers of two) where the resolver
    switches to flat under a slow link with the given latency."""
    fab = ("synthetic", (LinkSpec(1e-6, 1.0 / 50e9),
                        LinkSpec(slow_alpha, 1.0 / 6.25e9)))
    for exp in range(4, 18):
        plan = tuning.resolve_plan(_auto_cfg(), model_size=4,
                                   tokens_per_shard=2 ** exp, d_model=128,
                                   dtype="float32", fabric=fab)
        if plan.a2a == "flat":
            return 2 ** exp
    return 2 ** 18


def test_crossover_payload_grows_with_slow_link_latency():
    """Monotone crossover: the laggier the inter-node link, the longer
    hierarchical aggregation keeps winning (B* ∝ slow.alpha)."""
    thresholds = [_flat_crossover_T(a) for a in (1e-6, 1e-5, 1e-4)]
    assert thresholds == sorted(thresholds)
    assert thresholds[0] < thresholds[-1]


@pytest.mark.parametrize("T", [16, 100, 512, 4096])
@pytest.mark.parametrize("M", [2, 4, 8])
def test_resolved_overlap_always_divides_the_segment_bound(T, M):
    cfg = tuning.resolve_moe_config(_auto_cfg(), model_size=M,
                                    tokens_per_shard=T, d_model=256,
                                    dtype="float32")
    assert not tuning.has_auto_knobs(cfg)
    B = capacity.grouped_segment_bound(cfg, T, M)
    # grouped_overlap_chunk_bound raises when P ∤ B — it must not
    assert capacity.grouped_overlap_chunk_bound(cfg, B) * \
        cfg.overlap_chunks == B
    moe.validate_dispatch_config(cfg, model_size=M, tokens_per_shard=T)


# ---------------------------------------------------------------------------
# calibration: fit + TUNE_moe.json round-trip + corrupt-file fallback
# ---------------------------------------------------------------------------

def test_fit_alpha_beta_recovers_synthetic_link():
    alpha, beta = 2e-5, 1.0 / 8e9
    pts = [(b, alpha + beta * b) for b in (1e3, 1e5, 1e7, 1e9)]
    spec = tuning.fit_alpha_beta(pts)
    assert spec.alpha == pytest.approx(alpha, rel=1e-6)
    assert spec.beta == pytest.approx(beta, rel=1e-6)
    with pytest.raises(ValueError, match=">= 2"):
        tuning.fit_alpha_beta([(1e3, 1e-4)])


def test_calibration_round_trips_through_tune_json(tmp_path):
    path = tmp_path / "TUNE_moe.json"
    fast, slow = LinkSpec(3e-6, 1 / 40e9), LinkSpec(7e-5, 1 / 5e9)
    tuning.save_calibration(path, fast, slow)
    loaded = tuning.load_calibration(path)
    assert loaded is not None
    name, (lf, ls) = loaded
    assert name == "calibrated" and lf == fast and ls == slow
    # the persisted pair actually steers resolution
    plan = tuning.resolve_plan(_auto_cfg(), model_size=4,
                               tokens_per_shard=64, d_model=64,
                               dtype="float32", fabric=loaded)
    assert plan.fabric == "calibrated"


def test_corrupt_tune_json_falls_back_to_static_table(tmp_path):
    path = tmp_path / "TUNE_moe.json"
    path.write_text("{not json")
    assert tuning.load_calibration(path) is None
    path.write_text(json.dumps({"schema": "wrong/v0"}))
    assert tuning.load_calibration(path) is None
    assert tuning.load_calibration(tmp_path / "missing.json") is None
    # calibrate_fabric without a usable mesh persists the static default
    name, pair = tuning.calibrate_fabric(None, path=path)
    assert tuning.load_calibration(path) is not None
    assert pair[0].alpha > 0 and pair[1].alpha > 0


def test_configure_cli_modes():
    mode, fab = tuning.configure("off", "pcie_eth100")
    assert (mode, fab) == ("off", "pcie_eth100")
    # "off" pins the static defaults: resolution keeps flat/P1
    plan = tuning.resolve_plan(_auto_cfg(), model_size=4,
                               tokens_per_shard=16, d_model=32,
                               dtype="bfloat16")
    assert plan.a2a == "flat" and plan.overlap_chunks == 1
    mode, fab = tuning.configure("auto", "ici_dcn")
    assert (mode, fab) == ("auto", "ici_dcn")
    with pytest.raises(ValueError, match="--tune"):
        tuning.configure("fastest")


def test_parse_fabric_names_and_rejects_unknown():
    name, (fast, slow) = mesh_lib.parse_fabric(" ICI_DCN ")
    assert name == "ici_dcn"
    assert (fast, slow) == FABRICS["ici_dcn"]
    assert fast.alpha < slow.alpha        # fast dim really is faster
    with pytest.raises(ValueError) as e:
        mesh_lib.parse_fabric("nvlink")
    for valid in FABRICS:                 # error lists the valid fabrics
        assert valid in str(e.value)


# ---------------------------------------------------------------------------
# the shipped presets' "auto" knobs resolve on their documented meshes
# ---------------------------------------------------------------------------

# preset → model-axis sizes its docstring/production mesh implies:
# dbrx "1 expert per model-rank on the 16-wide model axis"; llama4 is
# the PRIMARY production target (16-wide model axis, launch/mesh.py);
# hetumoe-paper-16e reproduces the paper's N×8-GPU figures (G=8) and
# also runs the production 16-way axis.
PRESET_MESHES = {
    "hetumoe-paper-16e": (8, 16),
    "dbrx-132b": (16,),
    "llama4-maverick-400b-a17b": (16,),
}


@pytest.mark.parametrize("name", sorted(PRESET_MESHES))
def test_preset_auto_knobs_resolve_and_validate(name):
    cfg = configs.get_config(name)
    assert tuning.has_auto_knobs(cfg.moe)
    for M in PRESET_MESHES[name]:
        for dispatch in ("sort", "grouped"):
            for Tps in (64, 1024):
                mcfg = dataclasses.replace(cfg.moe, dispatch=dispatch)
                r = tuning.resolve_moe_config(
                    mcfg, model_size=M, tokens_per_shard=Tps,
                    d_model=cfg.d_model, dtype=cfg.dtype)
                assert not tuning.has_auto_knobs(r)
                # the resolver only emits combos the validator accepts
                moe.validate_dispatch_config(r, model_size=M,
                                             tokens_per_shard=Tps)
                if r.a2a == "hierarchical":
                    assert M % r.a2a_inner == 0


# ---------------------------------------------------------------------------
# serving: "auto" knobs must not cost a single extra retrace
# ---------------------------------------------------------------------------

def _trace_key_count(cfg, params, prompt, mesh):
    engine.clear_step_cache()
    a = generate(params, cfg, prompt, steps=4, mesh=mesh,
                 dispatch="grouped")
    first = dict(engine.trace_counts)
    assert first and all(v == 1 for v in first.values()), first
    b = generate(params, cfg, prompt, steps=4, mesh=mesh,
                 dispatch="grouped")
    assert dict(engine.trace_counts) == first, \
        "second identical generate() retraced under 'auto' knobs"
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    return len(first)


def test_auto_knobs_hit_step_cache_like_explicit_ints(mesh_ep4):
    cfg = configs.smoke_config("dbrx-132b").replace(dtype="float32")
    assert tuning.has_auto_knobs(cfg.moe)   # presets ship "auto" now
    explicit = cfg.replace(moe=dataclasses.replace(
        cfg.moe, a2a="flat", a2a_inner=1, overlap_chunks=1,
        grouped_block_m=None, grouped_ep_bound_factor=None))
    params = T.init_model(RNG, cfg)
    prompt = jax.random.randint(RNG, (2, 6), 0, cfg.vocab_size)
    n_auto = _trace_key_count(cfg, params, prompt, mesh_ep4)
    n_explicit = _trace_key_count(explicit, params, prompt, mesh_ep4)
    assert n_auto == n_explicit


def test_validate_decode_error_names_resolved_values(mesh_ep4):
    """P=3 cannot divide the (resolved) bound at this decode batch; the
    error must name the RESOLVED knobs, not the 'auto' sentinels."""
    cfg = configs.smoke_config("dbrx-132b")
    bad = cfg.replace(moe=dataclasses.replace(
        cfg.moe, dispatch="grouped", overlap_chunks=3))
    assert tuning.has_auto_knobs(bad.moe)   # a2a/block_m/factor still auto
    with pytest.raises(ValueError, match="auto-tuned: resolved"):
        engine.validate_decode_config(bad, mesh_ep4, 4)


def test_build_decode_keys_on_the_resolved_config(mesh_ep4):
    cfg = configs.smoke_config("dbrx-132b").replace(dtype="float32")
    cfg = engine.serve_config(cfg, dispatch="grouped")
    engine.clear_step_cache()
    s1 = engine.build_decode(cfg, mesh_ep4, batch=2)
    s2 = engine.build_decode(cfg, mesh_ep4, batch=2)
    assert s1 is s2                          # sentinel cfg, one resolved key
    resolved = engine.resolve_decode_config(cfg, mesh_ep4, 2)
    assert not tuning.has_auto_knobs(resolved.moe)
    s3 = engine.build_decode(resolved, mesh_ep4, batch=2)
    assert s1 is s3                          # resolved cfg IS the cache key


def test_auto_sentinel_accepted_by_config_validation():
    cfg = _auto_cfg()
    assert cfg.a2a == AUTO
    with pytest.raises(ValueError):
        MoEConfig(num_experts=8, a2a="fastest")
    with pytest.raises(ValueError):
        MoEConfig(num_experts=8, overlap_chunks="turbo")


# ---------------------------------------------------------------------------
# dtype is load-bearing: no silent bf16 guess, f32 vs bf16 cross over
# ---------------------------------------------------------------------------

def test_dtype_bytes_raises_on_none_and_knows_the_wire_dtypes():
    with pytest.raises(ValueError, match="dtype"):
        tuning._dtype_bytes(None)
    assert tuning._dtype_bytes("float32") == 4
    assert tuning._dtype_bytes("bfloat16") == 2
    assert tuning._dtype_bytes("int8") == 1


def test_resolve_plan_requires_a_concrete_dtype():
    with pytest.raises(ValueError, match="dtype"):
        tuning.resolve_plan(_auto_cfg(), model_size=4, tokens_per_shard=64,
                            d_model=128)


def test_f32_and_bf16_resolve_different_plans_at_the_crossover():
    """The 2-byte guess the old _dtype_bytes(None) made is exactly a
    factor-2 payload error: near the flat/hierarchical crossover, f32
    (4 B) and bf16 (2 B) runs of the SAME cell must resolve to
    DIFFERENT plans — f32 hits the flat regime one octave earlier."""
    fab = ("synthetic", (LinkSpec(1e-6, 1.0 / 50e9),
                         LinkSpec(1e-5, 1.0 / 6.25e9)))
    diff = None
    for exp in range(4, 18):
        kw = dict(model_size=4, tokens_per_shard=2 ** exp, d_model=128,
                  fabric=fab)
        p32 = tuning.resolve_plan(_auto_cfg(), dtype="float32", **kw)
        p16 = tuning.resolve_plan(_auto_cfg(), dtype="bfloat16", **kw)
        assert p32.payload_bytes == 2 * p16.payload_bytes
        if p32.a2a != p16.a2a:
            diff = (p32, p16)
            break
    assert diff is not None, "no T where the f32 and bf16 plans differ"
    assert diff[0].a2a == "flat" and diff[1].a2a == "hierarchical"


# ---------------------------------------------------------------------------
# payload_dtype="auto": quantize only when β dominates
# ---------------------------------------------------------------------------

def test_payload_auto_quantizes_beta_dominated_payloads():
    cfg = _auto_cfg(payload_dtype="auto")
    big = tuning.resolve_plan(cfg, model_size=4, tokens_per_shard=4096,
                              d_model=4096, dtype="bfloat16",
                              fabric="ici_dcn")
    assert big.payload_dtype == "int8"
    # the plan's wire bytes reflect the 1-byte payload (bf16 halved)
    unq = tuning.resolve_plan(_auto_cfg(), model_size=4,
                              tokens_per_shard=4096, d_model=4096,
                              dtype="bfloat16", fabric="ici_dcn")
    assert 2 * big.payload_bytes == unq.payload_bytes


def test_payload_auto_stays_lossless_when_alpha_dominates():
    cfg = _auto_cfg(payload_dtype="auto")
    small = tuning.resolve_plan(cfg, model_size=4, tokens_per_shard=1,
                                d_model=8, dtype="bfloat16",
                                fabric="ici_dcn")
    assert small.payload_dtype is None


def test_payload_auto_is_none_without_an_ep_exchange():
    cfg = _auto_cfg(payload_dtype="auto")
    # model_size == 1: the exchange is an identity — nothing to quantize
    plan = tuning.resolve_plan(cfg, model_size=1, tokens_per_shard=4096,
                               d_model=4096, dtype="bfloat16",
                               fabric="ici_dcn")
    assert plan.payload_dtype is None
    resolved = tuning.resolve_moe_config(
        cfg, model_size=1, tokens_per_shard=4096, d_model=4096,
        dtype="bfloat16")
    assert resolved.payload_dtype is None


def test_payload_auto_off_mode_and_explicit_pass_through():
    tuning.set_tuning(mode="off")
    plan = tuning.resolve_plan(_auto_cfg(payload_dtype="auto"),
                               model_size=4, tokens_per_shard=4096,
                               d_model=4096, dtype="bfloat16")
    assert plan.payload_dtype is None         # off = pre-quantization
    tuning.set_tuning(mode="auto")
    # an explicit fp8 choice is honored verbatim, never "upgraded"
    plan = tuning.resolve_plan(_auto_cfg(payload_dtype="float8_e4m3fn"),
                               model_size=4, tokens_per_shard=16,
                               d_model=32, dtype="bfloat16",
                               fabric="ici_dcn")
    assert plan.payload_dtype == "float8_e4m3fn"
    resolved = tuning.resolve_moe_config(
        _auto_cfg(payload_dtype="auto"), model_size=4,
        tokens_per_shard=4096, d_model=4096, dtype="bfloat16")
    assert resolved.payload_dtype == "int8"
    assert "payload_dtype" in tuning.describe_resolution(
        _auto_cfg(payload_dtype="auto"), resolved)
