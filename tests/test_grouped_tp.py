"""Grouped (dropless) dispatch × expert tensor parallelism.

The composition the TP fallback used to forfeit: ``dispatch="grouped"``
with ``expert_tp_axis`` set must run the ragged/grouped matmuls over
f-sliced expert weights — NOT silently rewrite itself to the
capacity-padded sort path.  Covers the full matrix: grouped+TP ≡
sort+TP ≡ dense ≡ no-TP (fwd + grad, f32/bf16), grouped+TP × grouped-EP
on the (data=2, model=2) mesh, both a2a modes, the Pallas kernel path,
and a jaxpr witness that the grouped primitives actually execute under
TP.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import analysis
from repro.core import moe
from repro.core.config import MoEConfig

RNG = jax.random.PRNGKey(7)
D = 32
E = 8


def _params(cfg, dtype=jnp.float32):
    return moe.init_moe_params(RNG, cfg, D, 64, cfg.num_experts,
                               act="swiglu", dtype=dtype)


def _apply(mesh, cfg, params, x, tp=None):
    return jax.jit(lambda p, v: moe.sharded_moe_apply(
        mesh, cfg, p, v, num_experts=cfg.num_experts, act="swiglu",
        expert_tp_axis=tp))(params, x)


def _cfg(dispatch, **kw):
    kw.setdefault("gate", "topk")
    kw.setdefault("top_k", 2)
    kw.setdefault("capacity_factor", 8.0)
    return MoEConfig(num_experts=E, dispatch=dispatch, **kw)


# ---------------------------------------------------------------------------
# the fallback is gone: grouped primitives execute under TP
# ---------------------------------------------------------------------------

def test_grouped_tp_runs_grouped_path_not_sort(mesh8):
    """The traced grouped+TP graph must contain the ragged grouped
    matmul equation (the dropless compute) — the old fallback lowered to
    the sort path's dense einsum and no ragged_dot appeared anywhere."""
    cfg = _cfg("grouped")
    p = _params(cfg)
    x = jax.random.normal(RNG, (8, 4, D))
    g = analysis.trace_graph(
        lambda p_, v: moe.sharded_moe_apply(mesh8, cfg, p_, v, num_experts=E,
                                            act="swiglu",
                                            expert_tp_axis="data"), p, x)
    assert g.count("ragged_dot") > 0
    # and the TP collectives surround it (gather the segments, reduce
    # the f-contraction) — the capacity-padded (E·C) buffer path would
    # show neither with these shapes
    assert g.count("all_gather") + g.count("all_gather_invariant") > 0
    assert g.count("psum_scatter") + g.count("reduce_scatter") > 0
    # every primitive sits outside scan/while bodies (statically
    # unrolled pipeline), so the loop-collective rule stays quiet
    assert analysis.run_rule("collective-in-loop", g) == []


# ---------------------------------------------------------------------------
# equivalence: grouped+TP ≡ sort+TP ≡ dense ≡ grouped no-TP
# ---------------------------------------------------------------------------

def test_grouped_tp_matches_sort_tp_and_dense(mesh8):
    x = jax.random.normal(RNG, (8, 8, D))
    p = _params(_cfg("sort"))
    y = {}
    y["grouped_tp"], _, _ = _apply(mesh8, _cfg("grouped"), p, x, tp="data")
    y["sort_tp"], _, _ = _apply(mesh8, _cfg("sort"), p, x, tp="data")
    y["dense"], _, _ = _apply(mesh8, _cfg("dense"), p, x)
    y["grouped"], _, _ = _apply(mesh8, _cfg("grouped"), p, x)
    for name in ("sort_tp", "dense", "grouped"):
        np.testing.assert_allclose(
            np.asarray(y["grouped_tp"]), np.asarray(y[name]),
            rtol=1e-4, atol=1e-5, err_msg=name)


def test_grouped_tp_matches_sort_tp_bf16(mesh8):
    """f32-accumulated grouped matmuls under TP stay within bf16
    rounding of the sort+TP path."""
    x = jax.random.normal(RNG, (8, 8, D), jnp.bfloat16)
    p = _params(_cfg("sort"), dtype=jnp.bfloat16)
    yg, _, _ = _apply(mesh8, _cfg("grouped"), p, x, tp="data")
    ys, _, _ = _apply(mesh8, _cfg("sort"), p, x, tp="data")
    np.testing.assert_allclose(np.asarray(yg, np.float32),
                               np.asarray(ys, np.float32),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("dtype,rtol", [
    (jnp.float32, 1e-4), (jnp.bfloat16, 2e-2)])
def test_grouped_tp_gradients_match_sort_tp(mesh8, dtype, rtol):
    """Same loss, same gradients (router AND f-sliced expert weights)
    through the grouped-TP collectives as through the sort-TP pair.

    f32 compares elementwise; bf16 compares norm-wise — the dispatch
    paths round the FFN outputs differently at bf16 ULP and the router
    gradient amplifies that elementwise (the same spread exists between
    sort and grouped WITHOUT TP), but the gradient as a vector must
    stay within bf16 accumulation error."""
    x = jax.random.normal(RNG, (8, 8, D), dtype)
    p = _params(_cfg("sort"), dtype=dtype)

    def loss_fn(cfg):
        def loss(p, v):
            y, aux, _ = moe.sharded_moe_apply(
                mesh8, cfg, p, v, num_experts=E, act="swiglu",
                expert_tp_axis="data")
            return jnp.sum(y.astype(jnp.float32) ** 2) + aux
        return jax.jit(jax.value_and_grad(loss))

    lg, gg = loss_fn(_cfg("grouped"))(p, x)
    ls, gs = loss_fn(_cfg("sort"))(p, x)
    np.testing.assert_allclose(float(lg), float(ls), rtol=rtol)
    for k in p:
        a = np.asarray(gg[k], np.float32)
        b = np.asarray(gs[k], np.float32)
        if dtype == jnp.float32:
            np.testing.assert_allclose(a, b, rtol=rtol, atol=1e-5,
                                       err_msg=k)
        else:
            err = np.linalg.norm(a - b) / np.linalg.norm(b)
            assert err < rtol, (k, err)
        assert np.linalg.norm(a) > 0, k


def test_grouped_tp_is_dropless_where_sort_drops(mesh8):
    """cf=0.25 starves sort+TP; grouped+TP ignores capacity_factor and
    reproduces the unconstrained reference on every token."""
    x = jax.random.normal(RNG, (8, 16, D))
    cfg_g = MoEConfig(num_experts=E, gate="switch", capacity_factor=0.25,
                      dispatch="grouped")
    cfg_ref = MoEConfig(num_experts=E, gate="switch", capacity_factor=16.0,
                        dispatch="sort")
    p = _params(cfg_g)
    yg, _, _ = _apply(mesh8, cfg_g, p, x, tp="data")
    yr, _, _ = _apply(mesh8, cfg_ref, p, x)
    np.testing.assert_allclose(np.asarray(yg), np.asarray(yr),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# grouped-TP × grouped-EP on the (data=2, model=2) mesh
# ---------------------------------------------------------------------------

def test_grouped_tp_with_grouped_ep(mesh1, mesh_dm22):
    """TP over ``data`` composed with the grouped AllToAll over
    ``model`` reproduces both the single-device grouped numerics and
    the sort+TP path on the same mesh."""
    cfg = _cfg("grouped")
    p = _params(cfg)
    x = jax.random.normal(RNG, (4, 16, D))
    y1, _, _ = _apply(mesh1, cfg, p, x)
    ytp, _, _ = _apply(mesh_dm22, cfg, p, x, tp="data")
    ysort, _, _ = _apply(mesh_dm22, _cfg("sort"), p, x, tp="data")
    np.testing.assert_allclose(np.asarray(ytp), np.asarray(y1),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ytp), np.asarray(ysort),
                               rtol=1e-4, atol=1e-5)


def test_grouped_tp_ep_hierarchical_equals_flat(mesh8):
    """TP × grouped-EP × the paper's two-stage a2a (model=4 →
    inner=2 × outer=2): identical output to the flat exchange."""
    x = jax.random.normal(RNG, (8, 8, D))
    cfgf = _cfg("grouped", gate="switch", top_k=1)
    cfgh = _cfg("grouped", gate="switch", top_k=1,
                a2a="hierarchical", a2a_inner=2)
    p = _params(cfgf)
    yf, _, _ = _apply(mesh8, cfgf, p, x, tp="data")
    yh, _, _ = _apply(mesh8, cfgh, p, x, tp="data")
    np.testing.assert_allclose(np.asarray(yf), np.asarray(yh),
                               rtol=1e-5, atol=1e-6)


def test_grouped_tp_ep_gradients_flow(mesh_dm22):
    cfg = _cfg("grouped", gate="switch", top_k=1, capacity_factor=4.0)
    p = _params(cfg)
    x = jax.random.normal(RNG, (4, 16, D))

    def loss(p, v):
        y, aux, _ = moe.sharded_moe_apply(
            mesh_dm22, cfg, p, v, num_experts=E, act="swiglu",
            expert_tp_axis="data")
        return jnp.sum(y ** 2) + aux

    g = jax.jit(jax.grad(loss))(p, x)
    for k, v in g.items():
        assert bool(jnp.all(jnp.isfinite(v))), k
        assert float(jnp.linalg.norm(v)) > 0, k


def test_grouped_tp_pallas_matches_jnp(mesh_dm22):
    """The Pallas gather/grouped-matmul kernels drive the TP×EP path
    end to end and agree with the jnp/ragged path, value and grad."""
    res = {}
    for pall in (False, True):
        cfg = _cfg("grouped", gate="switch", top_k=1, capacity_factor=2.0,
                   use_pallas_gate=pall)
        p = _params(cfg)
        x = jax.random.normal(RNG, (2, 16, D))

        def loss(p, v, cfg=cfg):
            y, aux, _ = moe.sharded_moe_apply(
                mesh_dm22, cfg, p, v, num_experts=E, act="swiglu",
                expert_tp_axis="data")
            return jnp.sum(y ** 2) + aux

        l, g = jax.jit(jax.value_and_grad(loss))(p, x)
        res[pall] = (float(l), float(jnp.linalg.norm(g["gate_w"])),
                     float(jnp.linalg.norm(g["w_up"])))
    np.testing.assert_allclose(res[False], res[True], rtol=1e-4)


def test_grouped_tp_tight_bound_stays_finite(mesh_dm22):
    """A binding grouped-EP segment bound under TP behaves like sort
    capacity: finite output, dropped rows ride the residual."""
    cfg = _cfg("grouped", gate="switch", top_k=1,
               grouped_ep_bound_factor=1.0)
    p = _params(cfg)
    x = jax.random.normal(RNG, (8, 16, D))
    y, aux, _ = _apply(mesh_dm22, cfg, p, x, tp="data")
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert bool(jnp.isfinite(aux))


def test_grouped_tp_token_padding_path(mesh_dm22):
    """T % n_dev != 0 (decode): virtual-expert rows stay out of the TP
    segment merge; output finite and equal to the sort+TP path."""
    cfg_g = _cfg("grouped", gate="switch", top_k=1)
    cfg_s = _cfg("sort", gate="switch", top_k=1)
    p = _params(cfg_g)
    x = jax.random.normal(RNG, (3, 1, D))
    yg, _, _ = _apply(mesh_dm22, cfg_g, p, x, tp="data")
    ys, _, _ = _apply(mesh_dm22, cfg_s, p, x, tp="data")
    assert yg.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(yg)))
    np.testing.assert_allclose(np.asarray(yg), np.asarray(ys),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# quantized exchange wire (PR 10) × TP: the int8/fp8 payload composes
# with expert tensor parallelism on the (data=2, model=2) mesh
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("qdt,out_tol,grad_tol", [
    ("int8", 5e-2, 1e-1),
    ("float8_e4m3fn", 1.5e-1, 3e-1),
])
def test_grouped_tp_ep_quantized_payload_fwd_and_grad(mesh_dm22, qdt,
                                                      out_tol, grad_tol):
    """Quantization touches only the model-axis exchange, so under TP
    over ``data`` the f-sliced grouped matmuls and their collectives
    must be reused unchanged: value and parameter gradients stay within
    the same per-dtype budgets as the EP-only cells (see
    test_grouped.QWIRE_TOLS for the measured medians)."""
    x = jax.random.normal(RNG, (4, 16, D))
    runs = {}
    for pd in (None, qdt):
        cfg = _cfg("grouped", gate="switch", top_k=1, capacity_factor=4.0,
                   payload_dtype=pd)
        p = _params(cfg)

        def loss(p, v, cfg=cfg):
            y, aux, _ = moe.sharded_moe_apply(
                mesh_dm22, cfg, p, v, num_experts=E, act="swiglu",
                expert_tp_axis="data")
            return jnp.sum(y ** 2) + aux, y

        (l, y), g = jax.jit(jax.value_and_grad(loss, has_aux=True))(p, x)
        runs[pd] = (float(l), np.asarray(y, np.float32),
                    {k: np.asarray(v, np.float32) for k, v in g.items()})

    l0, y0, g0 = runs[None]
    lq, yq, gq = runs[qdt]
    assert abs(lq - l0) / abs(l0) < out_tol
    assert np.linalg.norm(yq - y0) / np.linalg.norm(y0) < out_tol
    for k in g0:
        assert np.all(np.isfinite(gq[k])), k
        assert np.linalg.norm(gq[k]) > 0, k
        err = np.linalg.norm(gq[k] - g0[k]) / np.linalg.norm(g0[k])
        assert err < grad_tol, (qdt, k, err)
