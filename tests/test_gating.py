"""Unit + property tests for the 8 gating strategies (paper Fig. 2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import hypothesis, st

from repro.core import gating
from repro.core.config import MoEConfig

RNG = jax.random.PRNGKey(0)

ALL_GATES = [
    ("topk", dict(top_k=2)),
    ("switch", {}),
    ("gshard", {}),
    ("ktop1", dict(num_prototypes=2)),
    ("sam", dict(num_groups=4, top_k=2)),
    ("base", {}),
    ("hash", {}),
    ("dense_to_sparse", dict(top_k=2)),
]


# config corners that exercise each strategy's internal clamping: the
# static slot count gate_k() promises must be what route() emits, or
# capacity/bound sizing and the dispatch plans desync from the routing
OVERSIZED_GATES = [
    ("topk", dict(top_k=4)),
    ("switch", dict(top_k=4)),
    ("gshard", dict(top_k=4)),
    ("ktop1", dict(num_prototypes=4, top_k=4)),
    ("sam", dict(num_groups=4, top_k=8)),      # top_k > E/G: sam clamps
    ("base", dict(top_k=4)),
    ("hash", dict(top_k=4)),
    ("dense_to_sparse", dict(top_k=4)),
]


@pytest.mark.parametrize("gate,kw", ALL_GATES + OVERSIZED_GATES)
def test_gate_k_matches_route_width(gate, kw):
    """gate_k ≡ route() width for every strategy × config corner."""
    S, E = 32, 8
    cfg = MoEConfig(num_experts=E, gate=gate, **kw)
    logits = jax.random.normal(RNG, (S, E))
    out = gating.route(cfg, logits, rng=RNG, token_ids=jnp.arange(S))
    assert out.expert_index.shape == (S, gating.gate_k(cfg))
    assert out.combine_weights.shape == (S, gating.gate_k(cfg))


def test_gate_k_sam_clamps_to_group_width():
    """Regression: sam's top-k runs INSIDE the chosen group, so
    top_k > E/G yields E/G slots — gate_k used to return the raw top_k,
    tripping route()'s shape assert and over-sizing expert_capacity."""
    from repro.core import capacity
    cfg = MoEConfig(num_experts=8, gate="sam", num_groups=4, top_k=4)
    assert gating.gate_k(cfg) == 2
    out = gating.route(cfg, jax.random.normal(RNG, (16, 8)))
    assert out.expert_index.shape == (16, 2)
    # capacity and the grouped-EP bound size off the CLAMPED k
    cfg_eq = MoEConfig(num_experts=8, gate="sam", num_groups=4, top_k=2)
    assert (capacity.expert_capacity(cfg, 64, 8)
            == capacity.expert_capacity(cfg_eq, 64, 8))
    assert (capacity.grouped_segment_bound(cfg, 64, 4)
            == capacity.grouped_segment_bound(cfg_eq, 64, 4))


@pytest.mark.parametrize("gate,kw", ALL_GATES)
def test_gate_contract(gate, kw):
    """Every strategy: static shapes, indices in range, finite weights,
    probs a distribution."""
    S, E = 64, 8
    cfg = MoEConfig(num_experts=E, gate=gate, **kw)
    logits = jax.random.normal(RNG, (S, E))
    out = gating.route(cfg, logits, rng=RNG, token_ids=jnp.arange(S))
    k = gating.gate_k(cfg)
    assert out.expert_index.shape == (S, k)
    assert out.combine_weights.shape == (S, k)
    assert bool(jnp.all((out.expert_index >= 0) & (out.expert_index < E)))
    assert bool(jnp.all(jnp.isfinite(out.combine_weights)))
    assert bool(jnp.all(out.combine_weights >= 0))
    np.testing.assert_allclose(np.sum(np.asarray(out.router_probs), -1),
                               1.0, rtol=1e-4)


@hypothesis.given(S=st.integers(4, 200), E=st.sampled_from([2, 4, 8, 64]),
                  k=st.integers(1, 4), seed=st.integers(0, 2**30))
@hypothesis.settings(max_examples=25, deadline=None)
def test_topk_matches_lax(S, E, k, seed):
    k = min(k, E)
    cfg = MoEConfig(num_experts=E, gate="topk", top_k=k)
    logits = jax.random.normal(jax.random.PRNGKey(seed), (S, E))
    out = gating.route(cfg, logits)
    vals, idx = jax.lax.top_k(logits, k)
    np.testing.assert_array_equal(np.asarray(out.expert_index), np.asarray(idx))
    np.testing.assert_allclose(np.asarray(out.combine_weights),
                               np.asarray(jax.nn.softmax(vals, -1)), rtol=1e-5)


def test_switch_is_top1_of_softmax():
    cfg = MoEConfig(num_experts=8, gate="switch")
    logits = jax.random.normal(RNG, (32, 8))
    out = gating.route(cfg, logits)
    probs = jax.nn.softmax(logits, -1)
    np.testing.assert_array_equal(np.asarray(out.expert_index[:, 0]),
                                  np.asarray(jnp.argmax(probs, -1)))
    np.testing.assert_allclose(np.asarray(out.combine_weights[:, 0]),
                               np.asarray(jnp.max(probs, -1)), rtol=1e-5)


def test_gshard_weights_normalized_and_distinct():
    cfg = MoEConfig(num_experts=8, gate="gshard")
    logits = jax.random.normal(RNG, (64, 8))
    out = gating.route(cfg, logits, rng=RNG)
    assert bool(jnp.all(out.expert_index[:, 0] != out.expert_index[:, 1]))
    np.testing.assert_allclose(np.sum(np.asarray(out.combine_weights), -1),
                               1.0, rtol=1e-4)


def test_gshard_stochastic_second_never_repeats_first():
    """Regression: the old additive-eps log mask (log(masked + 1e-9))
    left the 1st expert's zeroed slot samplable whenever the other probs
    fell below eps — here p(i1) ≈ 1, so the categorical was near-uniform
    over ALL experts including i1 (~1/E re-pick rate per row).  The -inf
    mask makes re-picking impossible on every draw."""
    E = 8
    cfg = MoEConfig(num_experts=E, gate="gshard")
    # one dominant expert per row → all other probs ≈ 4e-18 ≪ 1e-9
    logits = jnp.zeros((256, E)).at[:, 3].set(40.0)
    for seed in range(20):
        out = gating.route(cfg, logits, rng=jax.random.PRNGKey(seed))
        assert bool(jnp.all(out.expert_index[:, 0] != out.expert_index[:, 1]))
        assert bool(jnp.all(jnp.isfinite(out.combine_weights)))


def test_ktop1_one_expert_per_prototype():
    P = 4
    cfg = MoEConfig(num_experts=16, gate="ktop1", num_prototypes=P)
    logits = jax.random.normal(RNG, (64, 16))
    out = gating.route(cfg, logits)
    per = 16 // P
    proto = np.asarray(out.expert_index) // per
    np.testing.assert_array_equal(proto, np.tile(np.arange(P), (64, 1)))


def test_sam_experts_within_one_group():
    G = 4
    cfg = MoEConfig(num_experts=16, gate="sam", num_groups=G, top_k=2)
    logits = jax.random.normal(RNG, (64, 16))
    out = gating.route(cfg, logits)
    per = 16 // G
    groups = np.asarray(out.expert_index) // per
    # both selected experts come from the SAME group (the SAM constraint
    # that avoids cross-device activation)
    assert (groups[:, 0] == groups[:, 1]).all()


def test_base_is_balanced():
    """Sinkhorn-BASE: loads far more balanced than greedy argmax."""
    S, E = 256, 8
    cfg = MoEConfig(num_experts=E, gate="base")
    # skewed logits: greedy would send everything to expert 0
    logits = jax.random.normal(RNG, (S, E)) + \
        jnp.array([3.0] + [0.0] * (E - 1))[None, :]
    out = gating.route(cfg, logits)
    counts = np.bincount(np.asarray(out.expert_index[:, 0]), minlength=E)
    greedy = np.bincount(np.asarray(jnp.argmax(logits, -1)), minlength=E)
    assert counts.max() < greedy.max()
    assert counts.max() <= S / E * 1.8, counts   # near-balanced


def test_hash_deterministic_and_id_based():
    cfg = MoEConfig(num_experts=8, gate="hash")
    ids = jnp.array([5, 5, 7, 5, 1])
    logits = jax.random.normal(RNG, (5, 8))
    a = gating.route(cfg, logits, token_ids=ids)
    b = gating.route(cfg, -logits, token_ids=ids)     # logits irrelevant
    np.testing.assert_array_equal(np.asarray(a.expert_index),
                                  np.asarray(b.expert_index))
    assert a.expert_index[0, 0] == a.expert_index[1, 0] == a.expert_index[3, 0]


def test_dense_to_sparse_annealing():
    """High T → near-uniform slot weights; low T → mass on slot 0."""
    E = 8
    logits = jax.random.normal(RNG, (128, E))
    hot = gating.route(MoEConfig(num_experts=E, gate="dense_to_sparse",
                                 top_k=4, gumbel_temperature=50.0), logits)
    cold = gating.route(MoEConfig(num_experts=E, gate="dense_to_sparse",
                                  top_k=4, gumbel_temperature=0.05), logits)
    spread_hot = float(jnp.mean(hot.combine_weights[:, 0]
                                - hot.combine_weights[:, -1]))
    mass_cold = float(jnp.mean(cold.combine_weights[:, 0]))
    assert spread_hot < 0.1          # dense phase: slots nearly equal
    # sparse phase: collapsed to top-1.  Not 1.0 even at T=0.05 — rows
    # whose top-2 logits nearly tie keep split mass (mean ≈0.948 here).
    assert mass_cold > 0.9


def test_aux_loss_uniform_is_one():
    from repro.core import balance
    S, E = 512, 8
    cfg = MoEConfig(num_experts=E, gate="switch")
    # uniform router → aux loss == 1 (its minimum)
    logits = jnp.zeros((S, E)) + jax.random.normal(RNG, (S, E)) * 1e-4
    out = gating.route(cfg, logits)
    lb = float(balance.load_balance_loss(out))
    assert abs(lb - 1.0) < 0.15
