"""Test fixtures.  8 fake CPU devices — enough for the multi-device
collective/EP tests while keeping compiles fast (NOT the 512-device
production mesh, which only launch/dryrun.py requests)."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

# Lock the backend to 8 devices NOW — importing repro.launch.dryrun later
# overwrites XLA_FLAGS (its production 512-device setting), which must not
# affect already-initialized test backends.
assert len(jax.devices()) == 8, jax.devices()


@pytest.fixture(scope="session")
def mesh1():
    from repro.launch.mesh import make_smoke_mesh
    return make_smoke_mesh((1, 1))


@pytest.fixture(scope="session")
def mesh8():
    from repro.launch.mesh import make_smoke_mesh
    return make_smoke_mesh((2, 4))


@pytest.fixture(scope="session")
def mesh_model8():
    from repro.launch.mesh import make_smoke_mesh
    return make_smoke_mesh((8,), ("model",))
