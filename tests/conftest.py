"""Test fixtures.  8 fake CPU devices — enough for the multi-device
collective/EP tests while keeping compiles fast (NOT the 512-device
production mesh, which only launch/dryrun.py requests)."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

# Lock the backend to 8 devices NOW — importing repro.launch.dryrun later
# overwrites XLA_FLAGS (its production 512-device setting), which must not
# affect already-initialized test backends.
assert len(jax.devices()) == 8, jax.devices()

# Fixtures whose tests exercise multi-device collectives: auto-tagged with
# the ``mesh`` marker (registered in pytest.ini) so `-m "not mesh"` gives
# a quick single-device pass without hand-marking every test.
MESH_FIXTURES = ("mesh8", "mesh_model8", "mesh_dm22", "mesh_ep4")


def pytest_collection_modifyitems(items):
    for item in items:
        names = getattr(item, "fixturenames", ())
        if any(f in names for f in MESH_FIXTURES):
            item.add_marker(pytest.mark.mesh)


@pytest.fixture(scope="session")
def mesh1():
    from repro.launch.mesh import make_smoke_mesh
    return make_smoke_mesh((1, 1))


@pytest.fixture(scope="session")
def mesh8():
    from repro.launch.mesh import make_smoke_mesh
    return make_smoke_mesh((2, 4))


@pytest.fixture(scope="session")
def mesh_model8():
    from repro.launch.mesh import make_smoke_mesh
    return make_smoke_mesh((8,), ("model",))


@pytest.fixture(scope="session")
def mesh_dm22():
    """(data=2, model=2) mesh — the grouped × expert-TP × grouped-EP
    composition tests: experts shard 2-way over ``model`` (the grouped
    AllToAll crosses it) while the expert weights' f dim shards 2-way
    over ``data`` (the expert-TP all-gather/psum_scatter crosses it)."""
    from repro.launch.mesh import make_smoke_mesh
    return make_smoke_mesh((2, 2))


@pytest.fixture(scope="session")
def mesh_ep4():
    """4-way pure expert-parallel mesh on the forced 8-device CPU
    backend — home of the grouped-EP ≡ sort ≡ dense equivalence tests
    (model axis only, so every collective crosses expert-parallel
    ranks; 4 ranks leaves room for hierarchical inner=2 × outer=2)."""
    from repro.launch.mesh import make_smoke_mesh
    return make_smoke_mesh((4,), ("model",))
