"""Dropless grouped dispatch + sort-once plan state + blocked kernels.

Covers the acceptance properties of the grouped mode: equivalence with
the sort path when capacity is non-binding, zero drops when it is, and
bit-identity of the blocked layout kernels against the jnp oracles
across block sizes including ragged tails.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import capacity, gating, layout, moe
from repro.core.config import MoEConfig
from repro.kernels import ref
from repro.kernels.grouped_ffn import grouped_ffn, grouped_matmul
from repro.kernels.layout_transform import gather_rows, scatter_add_rows

RNG = jax.random.PRNGKey(9)
D = 32


def _params(cfg, E, dtype=jnp.float32):
    return moe.init_moe_params(RNG, cfg, D, 64, E, act="swiglu", dtype=dtype)


# ---------------------------------------------------------------------------
# sort-once plan state
# ---------------------------------------------------------------------------

def test_plan_sort_carries_consistent_sort_state():
    S, E, k = 64, 8, 2
    cfg = MoEConfig(num_experts=E, gate="topk", top_k=k, capacity_factor=1.0)
    g = gating.route(cfg, jax.random.normal(RNG, (S, E)))
    C = capacity.expert_capacity(cfg, S, E)
    plan = layout.plan_sort(g, E, C)
    counts = np.asarray(plan.counts)
    offsets = np.asarray(plan.offsets)
    # counts are the pre-capacity per-expert assignment totals
    expect = np.bincount(np.asarray(g.expert_index).ravel(), minlength=E)
    np.testing.assert_array_equal(counts, expect)
    np.testing.assert_array_equal(offsets, np.concatenate(
        [[0], np.cumsum(counts)]))
    # the permutation really sorts the k-major expert ids, stably
    flat_e = np.asarray(g.expert_index).T.reshape(-1)
    order = np.asarray(plan.sort_order)
    assert (np.diff(flat_e[order]) >= 0).all()
    # inverse map agrees with the token-side slots
    inv = np.asarray(plan.inv)
    slot = np.asarray(plan.slot)
    for s in range(S):
        for j in range(k):
            if slot[s, j] >= 0:
                assert inv[slot[s, j]] == s
    assert (inv[np.setdiff1d(np.arange(E * C),
                             slot[slot >= 0].ravel())] == -1).all()


def test_dispatch_via_inv_equals_scatter():
    S, E = 96, 8
    cfg = MoEConfig(num_experts=E, gate="topk", top_k=2, capacity_factor=1.0)
    x = jax.random.normal(RNG, (S, D))
    g = gating.route(cfg, jax.random.normal(RNG, (S, E)))
    C = capacity.expert_capacity(cfg, S, E)
    plan = layout.plan_sort(g, E, C)
    buf = layout.dispatch_scatter(x, plan, E, C)      # inv-gather path
    fallback = plan._replace(sort_order=None, counts=None,
                             offsets=None, inv=None)
    buf2 = layout.dispatch_scatter(x, fallback, E, C)  # token-scatter path
    np.testing.assert_allclose(np.asarray(buf), np.asarray(buf2),
                               rtol=1e-6, atol=1e-6)


def test_plan_cumsum_counts_match_sort():
    S, E = 64, 8
    cfg = MoEConfig(num_experts=E, gate="topk", top_k=2, capacity_factor=1.0)
    g = gating.route(cfg, jax.random.normal(RNG, (S, E)))
    C = capacity.expert_capacity(cfg, S, E)
    p1 = layout.plan_sort(g, E, C)
    p2 = layout.plan_cumsum(g, E, C)
    np.testing.assert_array_equal(np.asarray(p1.slot), np.asarray(p2.slot))
    np.testing.assert_array_equal(np.asarray(p1.counts),
                                  np.asarray(p2.counts))
    np.testing.assert_array_equal(np.asarray(p1.offsets),
                                  np.asarray(p2.offsets))


# ---------------------------------------------------------------------------
# grouped mode: equivalence + dropless
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("gate,kw", [
    ("switch", {}), ("topk", dict(top_k=2)), ("gshard", {})])
def test_grouped_equals_sort_when_capacity_ample(mesh1, gate, kw):
    E = 8
    cfg_s = MoEConfig(num_experts=E, gate=gate, capacity_factor=8.0,
                      dispatch="sort", **kw)
    cfg_g = MoEConfig(num_experts=E, gate=gate, capacity_factor=8.0,
                      dispatch="grouped", **kw)
    p = _params(cfg_s, E)
    x = jax.random.normal(RNG, (4, 16, D))
    ys, auxs, ms = jax.jit(lambda p, v: moe.sharded_moe_apply(
        mesh1, cfg_s, p, v, num_experts=E, act="swiglu"))(p, x)
    yg, auxg, mg = jax.jit(lambda p, v: moe.sharded_moe_apply(
        mesh1, cfg_g, p, v, num_experts=E, act="swiglu"))(p, x)
    np.testing.assert_allclose(np.asarray(ys), np.asarray(yg), atol=1e-5)
    np.testing.assert_allclose(float(auxs), float(auxg), rtol=1e-6)
    np.testing.assert_allclose(float(ms["expert_load_max"]),
                               float(mg["expert_load_max"]), rtol=1e-6)


def test_grouped_matches_sort_in_bf16(mesh1):
    """Grouped matmuls accumulate f32 like the sort path's einsum, so
    bf16 params stay within bf16 rounding of the sort path."""
    E = 8
    cfg_s = MoEConfig(num_experts=E, gate="topk", top_k=2,
                      capacity_factor=8.0, dispatch="sort")
    cfg_g = MoEConfig(num_experts=E, gate="topk", top_k=2,
                      capacity_factor=8.0, dispatch="grouped")
    p = _params(cfg_s, E, dtype=jnp.bfloat16)
    x = jax.random.normal(RNG, (4, 16, D), jnp.bfloat16)
    ys, _, _ = jax.jit(lambda p, v: moe.sharded_moe_apply(
        mesh1, cfg_s, p, v, num_experts=E, act="swiglu"))(p, x)
    yg, _, _ = jax.jit(lambda p, v: moe.sharded_moe_apply(
        mesh1, cfg_g, p, v, num_experts=E, act="swiglu"))(p, x)
    np.testing.assert_allclose(np.asarray(ys, np.float32),
                               np.asarray(yg, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_grouped_is_dropless_where_sort_drops(mesh1):
    """cf=0.25 drops ~3/4 of tokens on the sort path; the grouped path
    computes every token and matches the no-drop reference everywhere."""
    E = 4
    cfg_s = MoEConfig(num_experts=E, gate="switch", capacity_factor=0.25,
                      dispatch="sort")
    cfg_g = MoEConfig(num_experts=E, gate="switch", capacity_factor=0.25,
                      dispatch="grouped")
    cfg_ref = MoEConfig(num_experts=E, gate="switch", capacity_factor=16.0,
                        dispatch="sort")
    p = _params(cfg_s, E)
    x = jax.random.normal(RNG, (8, 32, D))
    ys, _, _ = jax.jit(lambda p, v: moe.sharded_moe_apply(
        mesh1, cfg_s, p, v, num_experts=E, act="swiglu"))(p, x)
    yg, _, _ = jax.jit(lambda p, v: moe.sharded_moe_apply(
        mesh1, cfg_g, p, v, num_experts=E, act="swiglu"))(p, x)
    yr, _, _ = jax.jit(lambda p, v: moe.sharded_moe_apply(
        mesh1, cfg_ref, p, v, num_experts=E, act="swiglu"))(p, x)
    dropped = np.isclose(np.asarray(ys).reshape(-1, D), 0).all(axis=1)
    assert dropped.sum() > 64               # capacity really binds
    # grouped == unconstrained reference on every token, incl. dropped ones
    np.testing.assert_allclose(np.asarray(yg), np.asarray(yr), atol=1e-5)
    live = np.abs(np.asarray(yg).reshape(-1, D)).sum(axis=1)
    assert (live[dropped] > 0).all()        # zero tokens dropped


def test_grouped_pallas_matches_ragged(mesh1):
    E = 8
    res = {}
    for pall in (False, True):
        cfg = MoEConfig(num_experts=E, gate="switch", capacity_factor=2.0,
                        dispatch="grouped", use_pallas_gate=pall)
        p = _params(cfg, E)
        x = jax.random.normal(RNG, (2, 16, D))

        def loss(p, v):
            y, aux, _ = moe.sharded_moe_apply(mesh1, cfg, p, v,
                                              num_experts=E, act="swiglu")
            return jnp.sum(y ** 2) + aux

        l, g = jax.jit(jax.value_and_grad(loss))(p, x)
        res[pall] = (float(l), float(jnp.linalg.norm(g["gate_w"])),
                     float(jnp.linalg.norm(g["w_up"])))
    np.testing.assert_allclose(res[False], res[True], rtol=1e-4)


def test_grouped_falls_back_to_sort_under_ep(mesh8):
    E = 8
    cfg_g = MoEConfig(num_experts=E, gate="switch", capacity_factor=4.0,
                      dispatch="grouped")
    cfg_s = MoEConfig(num_experts=E, gate="switch", capacity_factor=4.0,
                      dispatch="sort")
    p = _params(cfg_s, E)
    x = jax.random.normal(RNG, (4, 16, D))
    yg, _, _ = jax.jit(lambda p, v: moe.sharded_moe_apply(
        mesh8, cfg_g, p, v, num_experts=E, act="swiglu"))(p, x)
    ys, _, _ = jax.jit(lambda p, v: moe.sharded_moe_apply(
        mesh8, cfg_s, p, v, num_experts=E, act="swiglu"))(p, x)
    np.testing.assert_allclose(np.asarray(yg), np.asarray(ys), atol=1e-6)


# ---------------------------------------------------------------------------
# blocked kernels: bit-identity vs jnp across block sizes + ragged tails
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("N,M,d,bm", [
    (64, 64, 128, 16),     # exact multiple
    (100, 37, 64, 8),      # ragged tail (37 % 8 != 0)
    (8, 5, 16, 128),       # M < block_m
    (3, 200, 8, 64),       # tiny source, many rows
    (33, 130, 8, 128),     # one full + one ragged block
])
def test_blocked_gather_bit_identical(N, M, d, bm):
    key = jax.random.PRNGKey(N * M)
    src = jax.random.normal(key, (N, d))
    idx = jax.random.randint(key, (M,), -2, N)
    out = gather_rows(src, idx, True, bm)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.ref_gather_rows(src, idx)))


@pytest.mark.parametrize("N,M,d,bm", [
    (64, 64, 32, 16), (16, 37, 8, 8), (8, 5, 16, 128)])
def test_blocked_scatter_add_matches_jnp(N, M, d, bm):
    key = jax.random.PRNGKey(M)
    g = jax.random.normal(key, (M, d))
    idx = jax.random.randint(key, (M,), -2, N)       # dups + drops
    out = scatter_add_rows(g, idx, N, interpret=True, block_m=bm)
    expect = np.zeros((N, d), np.float32)
    for j, i in enumerate(np.asarray(idx)):
        if i >= 0:
            expect[i] += np.asarray(g)[j]
    np.testing.assert_allclose(np.asarray(out), expect, rtol=1e-6, atol=1e-6)


def test_blocked_gather_vjp_uses_blocked_scatter():
    src = jax.random.normal(RNG, (8, 16))
    idx = jnp.array([0, 0, 3, -1, 7])
    for bm in (2, 128):
        g = jax.grad(lambda s: jnp.sum(gather_rows(s, idx, True, bm) ** 2))(src)
        out = np.asarray(ref.ref_gather_rows(src, idx))
        expect = np.zeros((8, 16), np.float32)
        for j, i in enumerate([0, 0, 3, -1, 7]):
            if i >= 0:
                expect[i] += 2 * out[j]
        np.testing.assert_allclose(np.asarray(g), expect, rtol=1e-5)


# ---------------------------------------------------------------------------
# grouped matmul kernel vs lax.ragged_dot
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,K,N,E,bm,tail", [
    (64, 16, 24, 4, 16, 0),
    (100, 8, 8, 3, 128, 7),     # virtual-bucket tail rows → zeros
    (37, 32, 16, 5, 8, 3),      # ragged blocks + tail
])
def test_grouped_matmul_matches_ragged_dot(M, K, N, E, bm, tail):
    k1, k2 = jax.random.split(jax.random.PRNGKey(M), 2)
    lhs = jax.random.normal(k1, (M, K))
    rhs = jax.random.normal(k2, (E, K, N))
    total = M - tail
    cuts = np.sort(np.random.RandomState(0).randint(0, total + 1, E - 1))
    sizes = jnp.array(np.diff(np.concatenate([[0], cuts, [total]])),
                      jnp.int32)
    out = grouped_matmul(lhs, rhs, sizes, True, bm)
    expect = jax.lax.ragged_dot(lhs, rhs, sizes)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)
    if tail:
        assert np.allclose(np.asarray(out)[total:], 0.0)
    g1 = jax.grad(lambda l, r: jnp.sum(
        grouped_matmul(l, r, sizes, True, bm) ** 2), (0, 1))(lhs, rhs)
    g2 = jax.grad(lambda l, r: jnp.sum(
        jax.lax.ragged_dot(l, r, sizes) ** 2), (0, 1))(lhs, rhs)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


def test_grouped_ffn_paths_agree():
    E, d, f = 4, 16, 32
    key = jax.random.PRNGKey(2)
    params = {
        "w_up": jax.random.normal(key, (E, d, f)),
        "w_gate": jax.random.normal(key, (E, d, f)),
        "w_out": jax.random.normal(key, (E, f, d)),
    }
    xs = jax.random.normal(key, (64, d))
    sizes = jnp.array([20, 10, 4, 30], jnp.int32)
    y1 = grouped_ffn(params, xs, sizes, "swiglu", use_pallas=False)
    y2 = grouped_ffn(params, xs, sizes, "swiglu", use_pallas=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
