"""Dropless grouped dispatch + sort-once plan state + blocked kernels.

Covers the acceptance properties of the grouped mode: equivalence with
the sort path when capacity is non-binding, zero drops when it is, and
bit-identity of the blocked layout kernels against the jnp oracles
across block sizes including ragged tails.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import analysis
from repro.core import capacity, gating, layout, moe
from repro.core.config import MoEConfig
from repro.kernels import ref
from repro.kernels.grouped_ffn import grouped_ffn, grouped_matmul
from repro.kernels.layout_transform import gather_rows, scatter_add_rows

RNG = jax.random.PRNGKey(9)
D = 32


def _params(cfg, E, dtype=jnp.float32):
    return moe.init_moe_params(RNG, cfg, D, 64, E, act="swiglu", dtype=dtype)


# ---------------------------------------------------------------------------
# sort-once plan state
# ---------------------------------------------------------------------------

def test_plan_sort_carries_consistent_sort_state():
    S, E, k = 64, 8, 2
    cfg = MoEConfig(num_experts=E, gate="topk", top_k=k, capacity_factor=1.0)
    g = gating.route(cfg, jax.random.normal(RNG, (S, E)))
    C = capacity.expert_capacity(cfg, S, E)
    plan = layout.plan_sort(g, E, C)
    counts = np.asarray(plan.counts)
    offsets = np.asarray(plan.offsets)
    # counts are the pre-capacity per-expert assignment totals
    expect = np.bincount(np.asarray(g.expert_index).ravel(), minlength=E)
    np.testing.assert_array_equal(counts, expect)
    np.testing.assert_array_equal(offsets, np.concatenate(
        [[0], np.cumsum(counts)]))
    # the permutation really sorts the k-major expert ids, stably
    flat_e = np.asarray(g.expert_index).T.reshape(-1)
    order = np.asarray(plan.sort_order)
    assert (np.diff(flat_e[order]) >= 0).all()
    # inverse map agrees with the token-side slots
    inv = np.asarray(plan.inv)
    slot = np.asarray(plan.slot)
    for s in range(S):
        for j in range(k):
            if slot[s, j] >= 0:
                assert inv[slot[s, j]] == s
    assert (inv[np.setdiff1d(np.arange(E * C),
                             slot[slot >= 0].ravel())] == -1).all()


def test_dispatch_via_inv_equals_scatter():
    S, E = 96, 8
    cfg = MoEConfig(num_experts=E, gate="topk", top_k=2, capacity_factor=1.0)
    x = jax.random.normal(RNG, (S, D))
    g = gating.route(cfg, jax.random.normal(RNG, (S, E)))
    C = capacity.expert_capacity(cfg, S, E)
    plan = layout.plan_sort(g, E, C)
    buf = layout.dispatch_scatter(x, plan, E, C)      # inv-gather path
    fallback = plan._replace(sort_order=None, counts=None,
                             offsets=None, inv=None)
    buf2 = layout.dispatch_scatter(x, fallback, E, C)  # token-scatter path
    np.testing.assert_allclose(np.asarray(buf), np.asarray(buf2),
                               rtol=1e-6, atol=1e-6)


def test_plan_cumsum_counts_match_sort():
    S, E = 64, 8
    cfg = MoEConfig(num_experts=E, gate="topk", top_k=2, capacity_factor=1.0)
    g = gating.route(cfg, jax.random.normal(RNG, (S, E)))
    C = capacity.expert_capacity(cfg, S, E)
    p1 = layout.plan_sort(g, E, C)
    p2 = layout.plan_cumsum(g, E, C)
    np.testing.assert_array_equal(np.asarray(p1.slot), np.asarray(p2.slot))
    np.testing.assert_array_equal(np.asarray(p1.counts),
                                  np.asarray(p2.counts))
    np.testing.assert_array_equal(np.asarray(p1.offsets),
                                  np.asarray(p2.offsets))


# ---------------------------------------------------------------------------
# grouped mode: equivalence + dropless
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("gate,kw", [
    ("switch", {}), ("topk", dict(top_k=2)), ("gshard", {})])
def test_grouped_equals_sort_when_capacity_ample(mesh1, gate, kw):
    E = 8
    cfg_s = MoEConfig(num_experts=E, gate=gate, capacity_factor=8.0,
                      dispatch="sort", **kw)
    cfg_g = MoEConfig(num_experts=E, gate=gate, capacity_factor=8.0,
                      dispatch="grouped", **kw)
    p = _params(cfg_s, E)
    x = jax.random.normal(RNG, (4, 16, D))
    ys, auxs, ms = jax.jit(lambda p, v: moe.sharded_moe_apply(
        mesh1, cfg_s, p, v, num_experts=E, act="swiglu"))(p, x)
    yg, auxg, mg = jax.jit(lambda p, v: moe.sharded_moe_apply(
        mesh1, cfg_g, p, v, num_experts=E, act="swiglu"))(p, x)
    np.testing.assert_allclose(np.asarray(ys), np.asarray(yg), atol=1e-5)
    np.testing.assert_allclose(float(auxs), float(auxg), rtol=1e-6)
    np.testing.assert_allclose(float(ms["expert_load_max"]),
                               float(mg["expert_load_max"]), rtol=1e-6)


def test_grouped_matches_sort_in_bf16(mesh1):
    """Grouped matmuls accumulate f32 like the sort path's einsum, so
    bf16 params stay within bf16 rounding of the sort path."""
    E = 8
    cfg_s = MoEConfig(num_experts=E, gate="topk", top_k=2,
                      capacity_factor=8.0, dispatch="sort")
    cfg_g = MoEConfig(num_experts=E, gate="topk", top_k=2,
                      capacity_factor=8.0, dispatch="grouped")
    p = _params(cfg_s, E, dtype=jnp.bfloat16)
    x = jax.random.normal(RNG, (4, 16, D), jnp.bfloat16)
    ys, _, _ = jax.jit(lambda p, v: moe.sharded_moe_apply(
        mesh1, cfg_s, p, v, num_experts=E, act="swiglu"))(p, x)
    yg, _, _ = jax.jit(lambda p, v: moe.sharded_moe_apply(
        mesh1, cfg_g, p, v, num_experts=E, act="swiglu"))(p, x)
    np.testing.assert_allclose(np.asarray(ys, np.float32),
                               np.asarray(yg, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_grouped_is_dropless_where_sort_drops(mesh1):
    """cf=0.25 drops ~3/4 of tokens on the sort path; the grouped path
    computes every token and matches the no-drop reference everywhere."""
    E = 4
    cfg_s = MoEConfig(num_experts=E, gate="switch", capacity_factor=0.25,
                      dispatch="sort")
    cfg_g = MoEConfig(num_experts=E, gate="switch", capacity_factor=0.25,
                      dispatch="grouped")
    cfg_ref = MoEConfig(num_experts=E, gate="switch", capacity_factor=16.0,
                        dispatch="sort")
    p = _params(cfg_s, E)
    x = jax.random.normal(RNG, (8, 32, D))
    ys, _, _ = jax.jit(lambda p, v: moe.sharded_moe_apply(
        mesh1, cfg_s, p, v, num_experts=E, act="swiglu"))(p, x)
    yg, _, _ = jax.jit(lambda p, v: moe.sharded_moe_apply(
        mesh1, cfg_g, p, v, num_experts=E, act="swiglu"))(p, x)
    yr, _, _ = jax.jit(lambda p, v: moe.sharded_moe_apply(
        mesh1, cfg_ref, p, v, num_experts=E, act="swiglu"))(p, x)
    dropped = np.isclose(np.asarray(ys).reshape(-1, D), 0).all(axis=1)
    assert dropped.sum() > 64               # capacity really binds
    # grouped == unconstrained reference on every token, incl. dropped ones
    np.testing.assert_allclose(np.asarray(yg), np.asarray(yr), atol=1e-5)
    live = np.abs(np.asarray(yg).reshape(-1, D)).sum(axis=1)
    assert (live[dropped] > 0).all()        # zero tokens dropped


def test_grouped_pallas_matches_ragged(mesh1):
    E = 8
    res = {}
    for pall in (False, True):
        cfg = MoEConfig(num_experts=E, gate="switch", capacity_factor=2.0,
                        dispatch="grouped", use_pallas_gate=pall)
        p = _params(cfg, E)
        x = jax.random.normal(RNG, (2, 16, D))

        def loss(p, v):
            y, aux, _ = moe.sharded_moe_apply(mesh1, cfg, p, v,
                                              num_experts=E, act="swiglu")
            return jnp.sum(y ** 2) + aux

        l, g = jax.jit(jax.value_and_grad(loss))(p, x)
        res[pall] = (float(l), float(jnp.linalg.norm(g["gate_w"])),
                     float(jnp.linalg.norm(g["w_up"])))
    np.testing.assert_allclose(res[False], res[True], rtol=1e-4)


# ---------------------------------------------------------------------------
# grouped expert parallelism: the grouped AllToAll (no more sort fallback)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("a2a,inner", [("flat", 1), ("hierarchical", 2)])
def test_grouped_ep_matches_sort_and_dense(mesh8, a2a, inner):
    """Ample capacity (no drops anywhere): grouped-EP ≡ sort ≡ dense on
    the 2×4 mesh, with both the flat and the hierarchical exchange."""
    E = 8
    x = jax.random.normal(RNG, (4, 16, D))
    ys = {}
    for mode in ("grouped", "sort", "dense"):
        cfg = MoEConfig(num_experts=E, gate="topk", top_k=2,
                        capacity_factor=8.0, dispatch=mode,
                        a2a=a2a, a2a_inner=inner)
        p = _params(cfg, E)
        ys[mode], _, _ = jax.jit(lambda p, v, cfg=cfg: moe.sharded_moe_apply(
            mesh8, cfg, p, v, num_experts=E, act="swiglu"))(p, x)
    np.testing.assert_allclose(np.asarray(ys["grouped"]),
                               np.asarray(ys["sort"]), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ys["grouped"]),
                               np.asarray(ys["dense"]), rtol=1e-4, atol=1e-5)


def test_grouped_ep_matches_single_device(mesh1, mesh_ep4):
    """4-way grouped EP reproduces the single-device grouped numerics."""
    E = 8
    cfg = MoEConfig(num_experts=E, gate="topk", top_k=2, capacity_factor=8.0,
                    dispatch="grouped")
    p = _params(cfg, E)
    x = jax.random.normal(RNG, (4, 16, D))
    y1, _, _ = jax.jit(lambda p, v: moe.sharded_moe_apply(
        mesh1, cfg, p, v, num_experts=E, act="swiglu"))(p, x)
    y4, _, _ = jax.jit(lambda p, v: moe.sharded_moe_apply(
        mesh_ep4, cfg, p, v, num_experts=E, act="swiglu"))(p, x)
    # (aux losses are per-shard means and legitimately differ by mesh —
    # same as the sort path; only the token outputs must agree)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y4),
                               rtol=1e-4, atol=1e-5)


def test_grouped_ep_hierarchical_equals_flat(mesh_ep4):
    """The paper's two-stage exchange composes with dropless dispatch:
    identical layer output either way (inner=2 × outer=2)."""
    E = 8
    x = jax.random.normal(RNG, (4, 16, D))
    cfgf = MoEConfig(num_experts=E, gate="switch", capacity_factor=4.0,
                     dispatch="grouped")
    cfgh = MoEConfig(num_experts=E, gate="switch", capacity_factor=4.0,
                     dispatch="grouped", a2a="hierarchical", a2a_inner=2)
    p = _params(cfgf, E)
    yf, _, _ = jax.jit(lambda p, v: moe.sharded_moe_apply(
        mesh_ep4, cfgf, p, v, num_experts=E, act="swiglu"))(p, x)
    yh, _, _ = jax.jit(lambda p, v: moe.sharded_moe_apply(
        mesh_ep4, cfgh, p, v, num_experts=E, act="swiglu"))(p, x)
    np.testing.assert_allclose(np.asarray(yf), np.asarray(yh),
                               rtol=1e-5, atol=1e-6)


def test_grouped_ep_is_dropless_where_sort_drops(mesh_ep4):
    """cf=0.25 starves the sort path; grouped-EP ignores capacity_factor
    and matches the unconstrained reference on every token."""
    E = 8
    cfg_g = MoEConfig(num_experts=E, gate="switch", capacity_factor=0.25,
                      dispatch="grouped")
    cfg_ref = MoEConfig(num_experts=E, gate="switch", capacity_factor=16.0,
                        dispatch="sort")
    p = _params(cfg_g, E)
    x = jax.random.normal(RNG, (8, 32, D))
    yg, _, _ = jax.jit(lambda p, v: moe.sharded_moe_apply(
        mesh_ep4, cfg_g, p, v, num_experts=E, act="swiglu"))(p, x)
    yr, _, _ = jax.jit(lambda p, v: moe.sharded_moe_apply(
        mesh_ep4, cfg_ref, p, v, num_experts=E, act="swiglu"))(p, x)
    np.testing.assert_allclose(np.asarray(yg), np.asarray(yr),
                               rtol=1e-4, atol=1e-5)


def test_grouped_ep_token_padding_path(mesh8):
    """Virtual-expert rows (3 tokens on 8 devices) never enter the
    exchange; output is finite and matches the sort path's."""
    E = 8
    cfg_g = MoEConfig(num_experts=E, gate="switch", capacity_factor=8.0,
                      dispatch="grouped")
    cfg_s = MoEConfig(num_experts=E, gate="switch", capacity_factor=8.0,
                      dispatch="sort")
    p = _params(cfg_g, E)
    x = jax.random.normal(RNG, (3, 1, D))
    yg, _, _ = jax.jit(lambda p, v: moe.sharded_moe_apply(
        mesh8, cfg_g, p, v, num_experts=E, act="swiglu"))(p, x)
    ys, _, _ = jax.jit(lambda p, v: moe.sharded_moe_apply(
        mesh8, cfg_s, p, v, num_experts=E, act="swiglu"))(p, x)
    assert yg.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(yg)))
    np.testing.assert_allclose(np.asarray(yg), np.asarray(ys),
                               rtol=1e-4, atol=1e-5)


def test_grouped_ep_gradients_flow(mesh8):
    cfg = MoEConfig(num_experts=8, gate="switch", capacity_factor=4.0,
                    dispatch="grouped")
    p = _params(cfg, 8)
    x = jax.random.normal(RNG, (4, 16, D))

    def loss(p, v):
        y, aux, _ = moe.sharded_moe_apply(mesh8, cfg, p, v,
                                          num_experts=8, act="swiglu")
        return jnp.sum(y ** 2) + aux

    g = jax.jit(jax.grad(loss))(p, x)
    for k, v in g.items():
        assert bool(jnp.all(jnp.isfinite(v))), k
        assert float(jnp.linalg.norm(v)) > 0, k


def test_grouped_ep_pallas_matches_jnp(mesh_ep4):
    """The Pallas gather/grouped-matmul kernels drive the EP exchange
    end to end and agree with the jnp/ragged path, value and grad."""
    E = 8
    res = {}
    for pall in (False, True):
        cfg = MoEConfig(num_experts=E, gate="switch", capacity_factor=2.0,
                        dispatch="grouped", use_pallas_gate=pall)
        p = _params(cfg, E)
        x = jax.random.normal(RNG, (2, 16, D))

        def loss(p, v, cfg=cfg):
            y, aux, _ = moe.sharded_moe_apply(mesh_ep4, cfg, p, v,
                                              num_experts=E, act="swiglu")
            return jnp.sum(y ** 2) + aux

        l, g = jax.jit(jax.value_and_grad(loss))(p, x)
        res[pall] = (float(l), float(jnp.linalg.norm(g["gate_w"])),
                     float(jnp.linalg.norm(g["w_up"])))
    np.testing.assert_allclose(res[False], res[True], rtol=1e-4)


@pytest.mark.parametrize("a2a,inner", [("flat", 1), ("hierarchical", 2)])
def test_grouped_ep_bound_drops_deterministically(mesh_ep4, a2a, inner):
    """``grouped_ep_bound_factor < 1`` drops EXACTLY the lowest-priority
    rows of each over-subscribed (source rank → dest rank) segment — the
    tail of the expert-sorted segment, so within each expert the kept
    rows are the stable sort's highest-priority prefix (slot-major:
    1st choices before 2nd choices, earlier tokens first) — identically
    across reruns and across both a2a modes; and the aux-loss load
    metrics still count the dropped assignments (they derive from the
    ROUTING counts, not the post-drop exchange counts)."""
    E, M = 8, 4
    cfg = MoEConfig(num_experts=E, gate="topk", top_k=2, capacity_factor=8.0,
                    dispatch="grouped", grouped_ep_bound_factor=0.5,
                    a2a=a2a, a2a_inner=inner)
    p = _params(cfg, E)
    x = jax.random.normal(RNG, (8, 16, D))        # 128 tokens, 32 per rank

    def fn(p, v):
        return moe.sharded_moe_apply(mesh_ep4, cfg, p, v,
                                     num_experts=E, act="swiglu")

    y1, _, m1 = jax.jit(fn)(p, x)
    y2, _, m2 = jax.jit(fn)(p, x)                 # fresh jit, same result
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    assert float(m1["expert_load_max"]) == float(m2["expert_load_max"])

    toks = np.asarray(x.reshape(-1, D))
    S_l = toks.shape[0] // M
    B = capacity.grouped_segment_bound(cfg, S_l, M)
    got = np.asarray(y1, np.float32).reshape(-1, D)
    load_max = []
    for m in range(M):
        xs = jnp.asarray(toks[m * S_l:(m + 1) * S_l])
        g = gating.route(cfg, gating.router_logits(cfg, xs, p["gate_w"]))
        gplan = layout.plan_grouped(g, E, drop_bucket=True)
        ep = layout.plan_grouped_ep(gplan, E, M, B)
        back = np.asarray(ep.back_map)
        sc = np.asarray(ep.send_counts).reshape(-1)   # (E,) routing order
        counts = np.asarray(gplan.counts)
        offs = np.asarray(gplan.offsets)
        # binding bound: something actually drops on this shard
        assert sc.sum() < counts.sum()
        # the kept rows of every expert segment are its PREFIX — the
        # highest-priority assignments survive, the tail drops
        for e in range(E):
            assert (back[offs[e]:offs[e] + sc[e]] >= 0).all()
            assert (back[offs[e] + sc[e]:offs[e + 1]] == -1).all()
        # expected per-token output: only surviving assignments contribute
        K = g.expert_index.shape[1]
        surv = np.zeros((S_l, K), bool)
        order = np.asarray(gplan.sort_order)
        token = np.asarray(gplan.token)
        for r in range(offs[E]):
            if back[r] >= 0:
                surv[token[r], order[r] // S_l] = True
        ye = moe.expert_ffn(
            {k: v for k, v in p.items() if k != "gate_w"},
            jnp.broadcast_to(xs, (E, S_l, D)), "swiglu")      # (E, S_l, d)
        w = np.asarray(g.combine_weights)
        idx = np.asarray(g.expert_index)
        expect = np.zeros((S_l, D), np.float32)
        for s in range(S_l):
            for k in range(K):
                if surv[s, k]:
                    expect[s] += w[s, k] * np.asarray(ye[idx[s, k], s],
                                                      np.float32)
        np.testing.assert_allclose(got[m * S_l:(m + 1) * S_l], expect,
                                   rtol=1e-4, atol=1e-5, err_msg=f"rank {m}")
        load_max.append(counts.max() / counts.sum())
    # load metrics count the dropped assignments: the pmean'd stat is the
    # shard mean of ROUTING-count maxima, not of the clipped send counts
    np.testing.assert_allclose(float(m1["expert_load_max"]),
                               np.mean(load_max), rtol=1e-5)


def test_grouped_ep_tight_bound_drops_gracefully(mesh_ep4):
    """A binding segment bound behaves like sort-path capacity: finite
    output, dropped rows fall back to the residual (zero layer output)."""
    E = 8
    cfg = MoEConfig(num_experts=E, gate="switch", capacity_factor=4.0,
                    dispatch="grouped", grouped_ep_bound_factor=1.0)
    p = _params(cfg, E)
    x = jax.random.normal(RNG, (8, 16, D))
    y, aux, _ = jax.jit(lambda p, v: moe.sharded_moe_apply(
        mesh_ep4, cfg, p, v, num_experts=E, act="swiglu"))(p, x)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert bool(jnp.isfinite(aux))


# ---------------------------------------------------------------------------
# grouped-EP plan state (send/receive maps, no collectives)
# ---------------------------------------------------------------------------

def test_grouped_ep_plan_maps_are_consistent():
    S, E, K, M = 64, 8, 2, 4
    cfg = MoEConfig(num_experts=E, gate="topk", top_k=K)
    g = gating.route(cfg, jax.random.normal(RNG, (S, E)))
    gplan = layout.plan_grouped(g, E, drop_bucket=True)
    B = S * K
    ep = layout.plan_grouped_ep(gplan, E, M, B)
    pack = np.asarray(ep.pack_map)
    back = np.asarray(ep.back_map)
    token = np.asarray(gplan.token)
    offsets = np.asarray(gplan.offsets)
    # every non-virtual sorted row has a slot, and the slot's pack entry
    # names the same source token
    for r in range(offsets[E]):
        assert back[r] >= 0
        assert pack[back[r]] == token[r]
    # virtual-bucket tail rows get no slot
    assert (back[offsets[E]:] == -1).all()
    # send_counts match the routing counts at the dropless bound
    E_local = E // M
    np.testing.assert_array_equal(
        np.asarray(ep.send_counts).reshape(-1), np.asarray(gplan.counts))
    # a binding bound truncates segment tails, never exceeds B
    ep2 = layout.plan_grouped_ep(gplan, E, M, 8)
    sc2 = np.asarray(ep2.send_counts)
    assert (sc2.sum(axis=1) <= 8).all()
    assert (sc2 <= np.asarray(gplan.counts).reshape(M, E_local)).all()


def test_grouped_ep_receive_maps_invert():
    M, E_local, B = 4, 2, 16
    rng = np.random.RandomState(0)
    counts = rng.randint(0, 6, (M, E_local)).astype(np.int32)
    ffn_src, dst_map, sizes = layout.grouped_ep_receive_maps(
        jnp.asarray(counts), B)
    ffn_src, dst_map = np.asarray(ffn_src), np.asarray(dst_map)
    np.testing.assert_array_equal(np.asarray(sizes), counts.sum(axis=0))
    # dst/src are mutual inverses on the live slots
    for i, dsti in enumerate(dst_map):
        if dsti >= 0:
            assert ffn_src[dsti] == i
    n = counts.sum()
    assert (np.sort(dst_map[dst_map >= 0]) == np.arange(n)).all()
    assert (ffn_src[n:] == -1).all()
    # FFN rows are expert-major: walking dst for chunk m visits local
    # expert segments in order
    e_of_ffn = np.searchsorted(np.cumsum(counts.sum(axis=0)),
                               np.arange(n), side="right")
    for m in range(M):
        off = 0
        for e in range(E_local):
            for j in range(counts[m, e]):
                assert e_of_ffn[dst_map[m * B + off + j]] == e
            off += counts[m, e]


# ---------------------------------------------------------------------------
# blocked kernels: bit-identity vs jnp across block sizes + ragged tails
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("N,M,d,bm", [
    (64, 64, 128, 16),     # exact multiple
    (100, 37, 64, 8),      # ragged tail (37 % 8 != 0)
    (8, 5, 16, 128),       # M < block_m
    (3, 200, 8, 64),       # tiny source, many rows
    (33, 130, 8, 128),     # one full + one ragged block
])
def test_blocked_gather_bit_identical(N, M, d, bm):
    key = jax.random.PRNGKey(N * M)
    src = jax.random.normal(key, (N, d))
    idx = jax.random.randint(key, (M,), -2, N)
    out = gather_rows(src, idx, True, bm)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.ref_gather_rows(src, idx)))


@pytest.mark.parametrize("N,M,d,bm", [
    (64, 64, 32, 16), (16, 37, 8, 8), (8, 5, 16, 128)])
def test_blocked_scatter_add_matches_jnp(N, M, d, bm):
    key = jax.random.PRNGKey(M)
    g = jax.random.normal(key, (M, d))
    idx = jax.random.randint(key, (M,), -2, N)       # dups + drops
    out = scatter_add_rows(g, idx, N, interpret=True, block_m=bm)
    expect = np.zeros((N, d), np.float32)
    for j, i in enumerate(np.asarray(idx)):
        if i >= 0:
            expect[i] += np.asarray(g)[j]
    np.testing.assert_allclose(np.asarray(out), expect, rtol=1e-6, atol=1e-6)


def test_blocked_gather_vjp_uses_blocked_scatter():
    src = jax.random.normal(RNG, (8, 16))
    idx = jnp.array([0, 0, 3, -1, 7])
    for bm in (2, 128):
        g = jax.grad(lambda s: jnp.sum(gather_rows(s, idx, True, bm) ** 2))(src)
        out = np.asarray(ref.ref_gather_rows(src, idx))
        expect = np.zeros((8, 16), np.float32)
        for j, i in enumerate([0, 0, 3, -1, 7]):
            if i >= 0:
                expect[i] += 2 * out[j]
        np.testing.assert_allclose(np.asarray(g), expect, rtol=1e-5)


# ---------------------------------------------------------------------------
# grouped matmul kernel vs lax.ragged_dot
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,K,N,E,bm,tail", [
    (64, 16, 24, 4, 16, 0),
    (100, 8, 8, 3, 128, 7),     # virtual-bucket tail rows → zeros
    (37, 32, 16, 5, 8, 3),      # ragged blocks + tail
])
def test_grouped_matmul_matches_ragged_dot(M, K, N, E, bm, tail):
    k1, k2 = jax.random.split(jax.random.PRNGKey(M), 2)
    lhs = jax.random.normal(k1, (M, K))
    rhs = jax.random.normal(k2, (E, K, N))
    total = M - tail
    cuts = np.sort(np.random.RandomState(0).randint(0, total + 1, E - 1))
    sizes = jnp.array(np.diff(np.concatenate([[0], cuts, [total]])),
                      jnp.int32)
    out = grouped_matmul(lhs, rhs, sizes, True, bm)
    expect = jax.lax.ragged_dot(lhs, rhs, sizes)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)
    if tail:
        assert np.allclose(np.asarray(out)[total:], 0.0)
    g1 = jax.grad(lambda l, r: jnp.sum(
        grouped_matmul(l, r, sizes, True, bm) ** 2), (0, 1))(lhs, rhs)
    g2 = jax.grad(lambda l, r: jnp.sum(
        jax.lax.ragged_dot(l, r, sizes) ** 2), (0, 1))(lhs, rhs)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


def test_grouped_ffn_paths_agree():
    E, d, f = 4, 16, 32
    key = jax.random.PRNGKey(2)
    params = {
        "w_up": jax.random.normal(key, (E, d, f)),
        "w_gate": jax.random.normal(key, (E, d, f)),
        "w_out": jax.random.normal(key, (E, f, d)),
    }
    xs = jax.random.normal(key, (64, d))
    sizes = jnp.array([20, 10, 4, 30], jnp.int32)
    y1 = grouped_ffn(params, xs, sizes, "swiglu", use_pallas=False)
    y2 = grouped_ffn(params, xs, sizes, "swiglu", use_pallas=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Pallas backward (dlhs / drhs kernels) vs the ragged_dot VJP
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype,rtol,atol", [
    (jnp.float32, 1e-5, 1e-5), (jnp.bfloat16, 3e-2, 3e-2)])
@pytest.mark.parametrize("bm", [16, 128])
def test_grouped_bwd_matches_ragged_vjp(dtype, rtol, atol, bm):
    """Explicit-cotangent VJP equality, with an EMPTY expert segment
    (expert 2) and 6 drop-bucket tail rows past offsets[-1]."""
    M, K, N, E = 96, 16, 24, 5
    lhs = jax.random.normal(RNG, (M, K)).astype(dtype)
    rhs = jax.random.normal(jax.random.PRNGKey(1), (E, K, N)).astype(dtype)
    sizes = jnp.array([30, 20, 0, 25, 15], jnp.int32)      # Σ = 90 < 96
    g = jax.random.normal(jax.random.PRNGKey(2), (M, N)).astype(dtype)

    _, vjp_p = jax.vjp(lambda l, r: grouped_matmul(l, r, sizes, True, bm),
                       lhs, rhs)
    _, vjp_r = jax.vjp(lambda l, r: jax.lax.ragged_dot(l, r, sizes),
                       lhs, rhs)
    (dl_p, dr_p), (dl_r, dr_r) = vjp_p(g), vjp_r(g)
    np.testing.assert_allclose(np.asarray(dl_p, np.float32),
                               np.asarray(dl_r, np.float32),
                               rtol=rtol, atol=atol)
    np.testing.assert_allclose(np.asarray(dr_p, np.float32),
                               np.asarray(dr_r, np.float32),
                               rtol=rtol, atol=atol)
    assert dl_p.dtype == lhs.dtype and dr_p.dtype == rhs.dtype
    # tail rows produce zero output, so their lhs gradient is zero
    assert np.allclose(np.asarray(dl_p, np.float32)[90:], 0.0)
    # an empty expert's weight gradient is exactly zero
    assert np.allclose(np.asarray(dr_p, np.float32)[2], 0.0)


def test_grouped_bwd_is_pallas_not_ragged_recompute():
    """The backward must run the dlhs/drhs kernels off the residuals —
    no ragged_dot equation (whose jax.vjp re-ran the whole forward)
    anywhere in the gradient graph, including custom_vjp sub-jaxprs."""
    lhs = jax.random.normal(RNG, (32, 8))
    rhs = jax.random.normal(RNG, (4, 8, 8))
    sizes = jnp.array([10, 6, 0, 16], jnp.int32)
    g = analysis.trace_graph(
        jax.grad(lambda l: jnp.sum(grouped_matmul(l, rhs, sizes, True,
                                                  16) ** 2)),
        lhs, context={"direction": "grad", "expect_no_ragged": True})
    assert analysis.run_rule("no-recompute-backward", g) == []
    # teeth: the raw lax.ragged_dot VJP *does* trip the same rule
    bad = analysis.trace_graph(
        jax.grad(lambda l: jnp.sum(jax.lax.ragged_dot(l, rhs, sizes) ** 2)),
        lhs, context={"direction": "grad", "expect_no_ragged": True})
    assert any(f.rule == "no-recompute-backward"
               for f in analysis.run_rule("no-recompute-backward", bad))


def test_grouped_ffn_swiglu_grads_pallas_matches_ragged():
    E, d, f = 4, 16, 32
    key = jax.random.PRNGKey(2)
    params = {
        "w_up": jax.random.normal(key, (E, d, f)),
        "w_gate": jax.random.normal(key, (E, d, f)),
        "w_out": jax.random.normal(key, (E, f, d)),
    }
    xs = jax.random.normal(key, (64, d))
    sizes = jnp.array([20, 10, 4, 28], jnp.int32)          # 2-row tail

    def loss(p, xs, use_pallas):
        return jnp.sum(grouped_ffn(p, xs, sizes, "swiglu",
                                   use_pallas=use_pallas, block_m=16) ** 2)

    gp, gxp = jax.grad(loss, (0, 1))(params, xs, True)
    gr, gxr = jax.grad(loss, (0, 1))(params, xs, False)
    np.testing.assert_allclose(np.asarray(gxp), np.asarray(gxr),
                               rtol=1e-4, atol=1e-4)
    for k in params:
        np.testing.assert_allclose(np.asarray(gp[k]), np.asarray(gr[k]),
                                   rtol=1e-4, atol=1e-4, err_msg=k)


def test_grouped_ep_pallas_grad_smoke(mesh_ep4):
    """jax.grad through the full grouped-EP layer on the Pallas kernel
    path (fwd + new bwd): finite, nonzero expert-weight gradients."""
    E = 8
    cfg = MoEConfig(num_experts=E, gate="switch", capacity_factor=2.0,
                    dispatch="grouped", use_pallas_gate=True)
    p = _params(cfg, E)
    x = jax.random.normal(RNG, (2, 16, D))

    def loss(p, v):
        y, aux, _ = moe.sharded_moe_apply(mesh_ep4, cfg, p, v,
                                          num_experts=E, act="swiglu")
        return jnp.sum(y ** 2) + aux

    g = jax.jit(jax.grad(loss))(p, x)
    for k, v in g.items():
        assert bool(jnp.all(jnp.isfinite(v))), k
        assert float(jnp.linalg.norm(v)) > 0, k


def test_grouped_block_m_threads_through_layer(mesh1):
    """cfg.grouped_block_m reaches the kernels; a non-default block size
    reproduces the default's output and gradients."""
    E = 8
    x = jax.random.normal(RNG, (2, 16, D))
    res = {}
    for bm in (None, 16):
        cfg = MoEConfig(num_experts=E, gate="switch", capacity_factor=2.0,
                        dispatch="grouped", use_pallas_gate=True,
                        grouped_block_m=bm)
        p = _params(cfg, E)

        def loss(p, v, cfg=cfg):
            y, aux, _ = moe.sharded_moe_apply(mesh1, cfg, p, v,
                                              num_experts=E, act="swiglu")
            return jnp.sum(y ** 2) + aux

        l, g = jax.jit(jax.value_and_grad(loss))(p, x)
        res[bm] = (float(l), float(jnp.linalg.norm(g["w_up"])))
    np.testing.assert_allclose(res[None], res[16], rtol=1e-5)


# ---------------------------------------------------------------------------
# quantized exchange wire (PR 10): int8 / fp8 payloads, fwd + grad
# ---------------------------------------------------------------------------

# Normwise relative-error budgets vs the unquantized grouped run (f32
# compute).  Measured on these shapes: int8 outputs land near 1.2%
# relative and e4m3 near 3.4%; gradients flow through the quantized
# backward (the cotangent takes the same wire), which roughly doubles
# the relative spread.  Budgets leave ~3x headroom over the medians.
QWIRE_TOLS = {"int8": (5e-2, 1e-1),
              "float8_e4m3fn": (1.5e-1, 3e-1)}


@pytest.mark.parametrize("qdt", sorted(QWIRE_TOLS))
def test_grouped_ep_quantized_payload_fwd_and_grad(mesh_ep4, qdt):
    """The low-precision exchange wire reproduces the unquantized
    grouped-EP layer — value AND parameter gradients — within the
    documented per-dtype budget, with every gradient finite/nonzero."""
    E = 8
    x = jax.random.normal(RNG, (4, 16, D))
    out_tol, grad_tol = QWIRE_TOLS[qdt]
    runs = {}
    for pd in (None, qdt):
        cfg = MoEConfig(num_experts=E, gate="switch", capacity_factor=4.0,
                        dispatch="grouped", payload_dtype=pd)
        p = _params(cfg, E)

        def loss(p, v, cfg=cfg):
            y, aux, _ = moe.sharded_moe_apply(mesh_ep4, cfg, p, v,
                                              num_experts=E, act="swiglu")
            return jnp.sum(y ** 2) + aux, y

        (l, y), g = jax.jit(jax.value_and_grad(loss, has_aux=True))(p, x)
        runs[pd] = (float(l), np.asarray(y, np.float32),
                    {k: np.asarray(v, np.float32) for k, v in g.items()})

    l0, y0, g0 = runs[None]
    lq, yq, gq = runs[qdt]
    assert abs(lq - l0) / abs(l0) < out_tol
    assert np.linalg.norm(yq - y0) / np.linalg.norm(y0) < out_tol
    for k in g0:
        assert np.all(np.isfinite(gq[k])), k
        assert np.linalg.norm(gq[k]) > 0, k
        err = np.linalg.norm(gq[k] - g0[k]) / np.linalg.norm(g0[k])
        assert err < grad_tol, (qdt, k, err)
