"""Fault-injection harness + every fault-tolerance guard, provable:
skip-step (NaN/Inf grads), dynamic loss scaling, crash-safe atomic
checkpointing with fallback restore, serving containment/backpressure."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import (CheckpointCorruptError, latest_step,
                              list_checkpoints, restore_checkpoint,
                              save_checkpoint)
from repro.core import faults as F
from repro.core.config import TrainConfig
from repro.data import SyntheticLM
from repro.models import transformer as T
from repro.serving import Request, SlotServer, generate
from repro.training import make_train_step
from repro.training.train_step import init_train_state

RNG = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# the harness itself
# ---------------------------------------------------------------------------

def test_fault_plan_addressing_and_log():
    plan = F.FaultPlan(sites={"a.b": F.FaultSpec(steps=(2, 5), mode="nan")})
    assert plan.fires("a.b", 1) is None
    assert plan.fires("a.b", 2) is not None
    assert plan.fires("nope", 2) is None
    assert plan.fired == [("a.b", 2)]
    always = F.FaultPlan(sites={"x": F.FaultSpec(mode="inf", always=True)})
    assert always.fires("x", 123) is not None


def test_plan_from_specs_cli_parsing():
    plan = F.plan_from_specs(["train.grads:nan@3,7", "serve.step:stall@*"])
    assert plan.sites["train.grads"].steps == (3, 7)
    assert plan.sites["serve.step"].always
    with pytest.raises(ValueError, match="site:mode@steps"):
        F.plan_from_specs(["garbage"])
    with pytest.raises(ValueError, match="mode"):
        F.plan_from_specs(["a:frobnicate@1"])


def test_host_seams_noop_without_plan():
    F.crash_point("any.site", 0)              # no ambient plan → no-op
    x = np.ones(4)
    assert F.inject_array("any.site", x, 0) is not None
    np.testing.assert_array_equal(F.inject_array("any.site", x, 0), x)


def test_inject_array_seeded_and_deterministic():
    plan = F.FaultPlan(sites={"s": F.FaultSpec(steps=(1,), mode="nan")}, seed=3)
    with F.active(plan):
        a = F.inject_array("s", np.ones(16), 1)
        b = F.inject_array("s", np.ones(16), 1)
    assert np.isnan(a).sum() == 1
    np.testing.assert_array_equal(a, b)


def test_corrupt_file_modes(tmp_path):
    p = tmp_path / "f.bin"
    p.write_bytes(bytes(range(256)))
    F.corrupt_file(str(p), mode="truncate")
    assert p.stat().st_size == 128
    p.write_bytes(bytes(range(256)))
    F.corrupt_file(str(p), mode="bitflip", seed=1)
    assert p.read_bytes() != bytes(range(256))
    assert p.stat().st_size == 256
    with pytest.raises(ValueError, match="bitflip"):
        F.corrupt_file(str(p), mode="nope")


# ---------------------------------------------------------------------------
# training: skip-step guard + loss scaling
# ---------------------------------------------------------------------------

def _tiny_train(tcfg, faults=None, steps=3, mesh=None):
    cfg = configs.smoke_config("starcoder2-3b").replace(dtype="float32")
    state = init_train_state(RNG, cfg, tcfg)
    ds = SyntheticLM(cfg, batch=2, seq_len=16)
    step = jax.jit(make_train_step(cfg, tcfg, mesh, faults=faults))
    states, metrics = [state], []
    for s in range(steps):
        state, m = step(state, ds.next_batch(s), jax.random.fold_in(RNG, s))
        states.append(state)
        metrics.append({k: float(v) for k, v in m.items()})
    return states, metrics


@pytest.mark.parametrize("site", ["train.grads", "train.loss",
                                  "train.activations"])
def test_nan_step_skipped_bitwise(site, mesh1):
    """An injected NaN at any seam skips the update: params AND opt state
    (moments + Adam count) keep their exact bits, counters advance."""
    plan = F.FaultPlan(sites={site: F.FaultSpec(steps=(1,), mode="nan")})
    states, metrics = _tiny_train(TrainConfig(total_steps=3, warmup_steps=1),
                                  faults=plan, steps=3, mesh=mesh1)
    before, after = states[1], states[2]          # step 1 is the bad step
    for a, b in zip(jax.tree.leaves((before.params, before.opt)),
                    jax.tree.leaves((after.params, after.opt))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert metrics[1]["skipped"] == 1 and metrics[1]["nonfinite_streak"] == 1
    assert int(after.step) == 2                   # data/step still advance
    # the NEXT step recovers and actually updates
    assert metrics[2]["nonfinite_streak"] == 0
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(states[2].params),
                        jax.tree.leaves(states[3].params)))
    assert changed


def test_clean_run_has_no_skips(mesh1):
    _, metrics = _tiny_train(TrainConfig(total_steps=3, warmup_steps=1),
                             steps=3, mesh=mesh1)
    assert all(m["skipped"] == 0 and m["nonfinite_streak"] == 0
               for m in metrics)


def test_streak_counts_consecutive(mesh1):
    plan = F.FaultPlan(sites={"train.grads":
                              F.FaultSpec(steps=(1, 2), mode="inf")})
    _, metrics = _tiny_train(TrainConfig(total_steps=4, warmup_steps=1),
                             faults=plan, steps=4, mesh=mesh1)
    assert [m["nonfinite_streak"] for m in metrics] == [0, 1, 2, 0]
    assert [m["skipped"] for m in metrics] == [0, 1, 2, 2]


def test_dynamic_loss_scale_halves_and_regrows(mesh1):
    tcfg = TrainConfig(total_steps=6, warmup_steps=1, loss_scale="dynamic",
                       loss_scale_growth_interval=2)
    plan = F.FaultPlan(sites={"train.loss": F.FaultSpec(steps=(1,),
                                                        mode="inf")})
    states, metrics = _tiny_train(tcfg, faults=plan, steps=4, mesh=mesh1)
    s0 = 2.0 ** 15
    assert [m["loss_scale"] for m in metrics] == [s0, s0 / 2, s0 / 2, s0]
    assert metrics[1]["skipped"] == 1
    # scaled training still actually trains (finite loss, params move)
    assert np.isfinite(metrics[-1]["loss"])


def test_static_loss_scale_grads_match_unscaled(mesh1):
    """A static scale changes the backward's dynamic range, not the
    update direction: one step with scale=1024 lands within float noise
    of the unscaled step."""
    t1 = TrainConfig(total_steps=2, warmup_steps=0)
    t2 = TrainConfig(total_steps=2, warmup_steps=0, loss_scale=1024.0)
    s1, _ = _tiny_train(t1, steps=1, mesh=mesh1)
    s2, _ = _tiny_train(t2, steps=1, mesh=mesh1)
    for a, b in zip(jax.tree.leaves(s1[-1].params),
                    jax.tree.leaves(s2[-1].params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=1e-5)


def test_train_config_validates_fault_knobs():
    with pytest.raises(ValueError, match="loss_scale"):
        TrainConfig(loss_scale="bogus")
    with pytest.raises(ValueError, match="loss_scale"):
        TrainConfig(loss_scale=-1.0)
    with pytest.raises(ValueError, match="max_skipped_steps"):
        TrainConfig(max_skipped_steps=0)


# ---------------------------------------------------------------------------
# checkpointing: atomicity, checksums, fallback, retention
# ---------------------------------------------------------------------------

def _toy_state(val=1.0):
    return {"w": jnp.full((4, 3), val, jnp.float32),
            "opt": {"m": jnp.full((4, 3), val * 0.1, jnp.float32),
                    "count": jnp.asarray(int(val), jnp.int32)}}


@pytest.mark.parametrize("site,expect_step", [
    ("ckpt.data_tmp_written", 1),       # killed before os.replace
    ("ckpt.data_replaced", 1),          # .npz in place, no manifest yet
    ("ckpt.manifest_step_written", 2),  # per-step manifest already durable
])
def test_crash_during_save_leaves_restorable_dir(tmp_path, site, expect_step):
    d = str(tmp_path)
    save_checkpoint(d, _toy_state(1.0), 1)
    plan = F.FaultPlan(sites={site: F.FaultSpec(steps=(2,), mode="raise")})
    with F.active(plan):
        with pytest.raises(F.FaultInjected):
            save_checkpoint(d, _toy_state(2.0), 2)
    state, step = restore_checkpoint(d, _toy_state(0.0))
    assert step == expect_step
    np.testing.assert_array_equal(np.asarray(state["w"]),
                                  np.full((4, 3), float(expect_step)))
    # a later clean save fully recovers the directory
    save_checkpoint(d, _toy_state(3.0), 3)
    _, step = restore_checkpoint(d, _toy_state(0.0))
    assert step == 3


@pytest.mark.parametrize("mode", ["truncate", "bitflip"])
def test_corrupt_latest_falls_back_to_previous(tmp_path, mode):
    d = str(tmp_path)
    for s in (1, 2, 3):
        save_checkpoint(d, _toy_state(float(s)), s)
    F.corrupt_file(os.path.join(d, "ckpt_00000003.npz"), mode=mode, seed=7)
    state, step = restore_checkpoint(d, _toy_state(0.0))
    assert step == 2
    np.testing.assert_array_equal(np.asarray(state["w"]), np.full((4, 3), 2.0))
    with pytest.raises(CheckpointCorruptError):
        restore_checkpoint(d, _toy_state(0.0), fallback=False)


def test_checksum_mismatch_is_corruption(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, _toy_state(1.0), 1)
    mp = os.path.join(d, "ckpt_00000001.json")
    with open(mp) as f:
        manifest = json.load(f)
    manifest["checksums"]["w"] ^= 0xFF
    with open(mp, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(CheckpointCorruptError, match="checksum"):
        restore_checkpoint(d, _toy_state(0.0), fallback=False)


def test_restore_names_missing_and_unexpected_keys(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, _toy_state(1.0), 1)
    bad_tpl = {"w": jnp.zeros((4, 3)), "extra": jnp.zeros(2)}
    with pytest.raises(ValueError) as ei:
        restore_checkpoint(d, bad_tpl)
    msg = str(ei.value)
    assert "missing" in msg and "extra" in msg
    assert "unexpected" in msg and "opt/m" in msg


def test_all_candidates_corrupt_raises_typed_error(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, _toy_state(1.0), 1)
    F.corrupt_file(os.path.join(d, "ckpt_00000001.npz"), mode="truncate")
    with pytest.raises(CheckpointCorruptError, match="no intact checkpoint"):
        restore_checkpoint(d, _toy_state(0.0))
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(str(tmp_path / "empty"), _toy_state(0.0))


def test_retention_keeps_last_k_and_cleans_tmp(tmp_path):
    d = str(tmp_path)
    open(os.path.join(d, "ckpt_99999999.npz.tmp"), "w").write("torn")
    for s in range(1, 6):
        save_checkpoint(d, _toy_state(float(s)), s, keep=2)
    steps = [s for s, _ in list_checkpoints(d)]
    assert steps == [5, 4]
    assert not [f for f in os.listdir(d) if f.endswith(".tmp")]
    assert latest_step(d) == 5
    assert latest_step(str(tmp_path / "nope")) is None


def test_legacy_manifest_only_dir_still_restores(tmp_path):
    """Pre-format-2 dirs (manifest.json only, no per-step manifests or
    checksums) remain restorable."""
    d = str(tmp_path)
    flat = {"w": np.ones((2, 2), np.float32)}
    path = os.path.join(d, "ckpt_00000007.npz")
    np.savez(path, **flat)
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump({"latest": path, "step": 7, "keys": ["w"]}, f)
    state, step = restore_checkpoint(d, {"w": jnp.zeros((2, 2))})
    assert step == 7


# ---------------------------------------------------------------------------
# serving: containment, rejection, backpressure, deadlines
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def serve_env(mesh1):
    cfg = configs.smoke_config("starcoder2-3b").replace(dtype="float32")
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    rng = jax.random.PRNGKey(1)
    prompts = [jax.random.randint(jax.random.fold_in(rng, i), (6,), 0,
                                  cfg.vocab_size) for i in range(4)]
    gen = 5
    refs = [np.asarray(generate(params, cfg, p[None, :], steps=gen,
                                mesh=mesh1))[0, 6:] for p in prompts]
    return cfg, params, prompts, refs, gen


def test_mixed_workload_drains_and_healthy_slots_unaffected(serve_env, mesh1):
    """Oversized + out-of-range + poisoned-prefill + poisoned-decode
    requests: the server drains everything, healthy outputs are bitwise
    the single-request greedy reference."""
    cfg, params, prompts, refs, gen = serve_env
    plan = F.FaultPlan(sites={
        "serve.prefill_logits": F.FaultSpec(steps=(1,), mode="nan"),
        "serve.step_logits": F.FaultSpec(steps=(2,), mode="inf"),
    })
    srv = SlotServer(cfg, params, slots=2, cache_len=6 + gen + 2, mesh=mesh1,
                     queue_limit=8)
    reqs = [Request(uid=i, prompt=p, max_new=gen)
            for i, p in enumerate(prompts)]
    reqs.append(Request(uid=10, prompt=jnp.zeros((64,), jnp.int32), max_new=3))
    reqs.append(Request(uid=11, prompt=jnp.full((4,), cfg.vocab_size, jnp.int32),
                        max_new=3))
    with F.active(plan):
        done = srv.run(reqs)
    by_uid = {r.uid: r for r in done}
    assert len(done) == 6 and all(r.done for r in done)
    assert by_uid[1].status == "failed" and "prefill" in by_uid[1].error
    assert by_uid[2].status == "failed" \
        and by_uid[2].error == "non_finite_decode_logits"
    assert by_uid[10].status == "rejected" \
        and by_uid[10].error.startswith("prompt_too_long")
    assert by_uid[11].status == "rejected" \
        and by_uid[11].error.startswith("token_out_of_range")
    for uid in (0, 3):
        assert by_uid[uid].status == "ok"
        np.testing.assert_array_equal(np.asarray(by_uid[uid].out), refs[uid])
    assert ("serve.prefill_logits", 1) in plan.fired
    assert ("serve.step_logits", 2) in plan.fired


def test_oversized_prompt_structured_rejection_no_prefill(serve_env, mesh1):
    cfg, params, prompts, refs, gen = serve_env
    srv = SlotServer(cfg, params, slots=1, cache_len=8, mesh=mesh1)
    big = Request(uid=0, prompt=jnp.zeros((8,), jnp.int32), max_new=2)
    assert srv.submit(big) is True                # consumed, not admitted
    assert big.status == "rejected" and big.done
    assert big.error == "prompt_too_long:8>cache_len-1=7"
    assert not srv.active
    edge = Request(uid=1, prompt=jnp.zeros((7,), jnp.int32), max_new=2)
    assert srv.submit(edge) is True and edge.status == "active"


def test_queue_backpressure_and_limit_validation(serve_env, mesh1):
    cfg, params, prompts, _, _ = serve_env
    srv = SlotServer(cfg, params, slots=1, cache_len=16, mesh=mesh1,
                     queue_limit=2)
    rs = [Request(uid=i, prompt=prompts[0], max_new=2) for i in range(3)]
    assert srv.enqueue(rs[0]) and srv.enqueue(rs[1])
    assert srv.enqueue(rs[2]) is False
    assert rs[2].status == "rejected" and rs[2].error == "queue_full"
    with pytest.raises(ValueError, match="queue_limit"):
        SlotServer(cfg, params, slots=1, cache_len=16, mesh=mesh1,
                   queue_limit=0)


def test_deadline_evicts_but_server_survives(serve_env, mesh1):
    cfg, params, prompts, refs, gen = serve_env
    srv = SlotServer(cfg, params, slots=2, cache_len=32, mesh=mesh1,
                     default_deadline_steps=2)
    slow = Request(uid=0, prompt=prompts[0], max_new=25)
    ok = Request(uid=1, prompt=prompts[1], max_new=gen,
                 deadline_steps=100)                   # per-request override
    done = srv.run([slow, ok])
    by_uid = {r.uid: r for r in done}
    assert by_uid[0].status == "evicted" and by_uid[0].error == "deadline"
    assert by_uid[0].steps_used == 2
    assert by_uid[1].status == "ok"
    np.testing.assert_array_equal(np.asarray(by_uid[1].out), refs[1])


def test_decode_row_poison_contained_under_grouped_dispatch(mesh1):
    """A poisoned grouped decode row (the ``serve.decode_row`` site,
    delivered inside the step-builder's compiled-step path) fails ONLY
    the slot whose row it lands in: the other in-flight slot and the
    refilled request finish bitwise equal to the grouped generate()
    reference."""
    cfg = configs.smoke_config("dbrx-132b").replace(dtype="float32")
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    gen = 5
    prompts = [jax.random.randint(jax.random.fold_in(RNG, i), (6,), 0,
                                  cfg.vocab_size) for i in range(3)]
    refs = [np.asarray(generate(params, cfg, p[None, :], steps=gen,
                                mesh=mesh1, dispatch="grouped"))[0, 6:]
            for p in prompts]
    plan = F.FaultPlan(sites={
        "serve.decode_row": F.FaultSpec(steps=(1,), mode="nan")})
    srv = SlotServer(cfg, params, slots=2, cache_len=6 + gen + 2, mesh=mesh1,
                     dispatch="grouped", queue_limit=8)
    reqs = [Request(uid=i, prompt=p, max_new=gen)
            for i, p in enumerate(prompts)]
    with F.active(plan):
        done = srv.run(reqs)
    assert ("serve.decode_row", 1) in plan.fired
    by_uid = {r.uid: r for r in done}
    assert len(done) == 3 and all(r.done for r in done)
    # exactly ONE request (the slot the seeded NaN landed in) failed;
    # which one is a function of the plan seed, not of scheduling
    failed = [r for r in done if r.status == "failed"]
    assert len(failed) == 1
    assert failed[0].error == "non_finite_decode_logits"
    for r in done:
        if r.status == "ok":
            np.testing.assert_array_equal(np.asarray(r.out), refs[r.uid],
                                          err_msg=f"uid={r.uid}")
    assert sum(r.status == "ok" for r in done) == 2


def test_stall_site_fires_without_breaking_decode(serve_env, mesh1):
    cfg, params, prompts, refs, gen = serve_env
    plan = F.FaultPlan(sites={"serve.step": F.FaultSpec(
        steps=(0,), mode="stall", stall_s=0.01)})
    srv = SlotServer(cfg, params, slots=1, cache_len=6 + gen + 2, mesh=mesh1)
    with F.active(plan):
        done = srv.run([Request(uid=0, prompt=prompts[0], max_new=gen)])
    assert done[0].status == "ok"
    np.testing.assert_array_equal(np.asarray(done[0].out), refs[0])
    assert ("serve.step", 0) in plan.fired
