"""Flash-attention Pallas kernels vs the jnp oracle (§Perf H3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import hypothesis, st

from repro.kernels.flash_attention import flash_attention

RNG = jax.random.PRNGKey(11)


def ref(q, k, v, q_pos, k_pos, scale, causal, window, cap):
    G = q.shape[1] // k.shape[1]
    kk = jnp.repeat(k, G, axis=1).astype(jnp.float32)
    vv = jnp.repeat(v, G, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kk) * scale
    if cap is not None:
        s = cap * jnp.tanh(s / cap)
    m = (k_pos >= 0)[None, :]
    if causal:
        m = m & (k_pos[None, :] <= q_pos[:, None])
    if window is not None:
        m = m & (k_pos[None, :] > q_pos[:, None] - window)
    s = jnp.where(m[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv).astype(q.dtype)


@pytest.mark.parametrize("B,H,KV,S,d,causal,win,cap", [
    (2, 4, 2, 64, 32, True, None, None),
    (1, 4, 1, 128, 16, True, 16, None),
    (2, 2, 2, 64, 32, False, None, 5.0),
    (1, 8, 2, 96, 32, True, None, 50.0),
])
def test_forward_matches_oracle(B, H, KV, S, d, causal, win, cap):
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (B, H, S, d), jnp.float32)
    k = jax.random.normal(ks[1], (B, KV, S, d), jnp.float32)
    v = jax.random.normal(ks[2], (B, KV, S, d), jnp.float32)
    pos = jnp.arange(S)
    scale = d ** -0.5
    o = flash_attention(q, k, v, pos, pos, scale, causal, win, cap, 32, True)
    r = ref(q, k, v, pos, pos, scale, causal, win, cap)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                               rtol=1e-5, atol=2e-5)


@pytest.mark.parametrize("causal,win,cap", [(True, None, None),
                                            (True, 16, None),
                                            (True, None, 30.0)])
def test_gradients_match_oracle(causal, win, cap):
    B, H, KV, S, d = 1, 4, 2, 64, 16
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (B, H, S, d), jnp.float32)
    k = jax.random.normal(ks[1], (B, KV, S, d), jnp.float32)
    v = jax.random.normal(ks[2], (B, KV, S, d), jnp.float32)
    pos = jnp.arange(S)
    scale = d ** -0.5
    f = lambda q, k, v: jnp.sum(
        flash_attention(q, k, v, pos, pos, scale, causal, win, cap, 32, True) ** 2)
    fr = lambda q, k, v: jnp.sum(ref(q, k, v, pos, pos, scale, causal, win, cap) ** 2)
    g = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(fr, argnums=(0, 1, 2))(q, k, v)
    for a, b, nm in zip(g, gr, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=5e-4, err_msg=nm)


@hypothesis.given(S=st.sampled_from([32, 64, 96]),
                  H=st.sampled_from([2, 4]), KV=st.sampled_from([1, 2]),
                  d=st.sampled_from([16, 32]),
                  dtype=st.sampled_from(["float32", "bfloat16"]),
                  seed=st.integers(0, 2**30))
@hypothesis.settings(max_examples=12, deadline=None)
def test_forward_sweep(S, H, KV, d, dtype, seed):
    B = 1
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    dt = jnp.dtype(dtype)
    q = jax.random.normal(ks[0], (B, H, S, d), dt)
    k = jax.random.normal(ks[1], (B, KV, S, d), dt)
    v = jax.random.normal(ks[2], (B, KV, S, d), dt)
    pos = jnp.arange(S)
    o = flash_attention(q, k, v, pos, pos, d ** -0.5, True, None, None, 32, True)
    r = ref(q, k, v, pos, pos, d ** -0.5, True, None, None)
    tol = 2e-5 if dtype == "float32" else 2e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), rtol=tol, atol=tol)


def test_model_flash_path_matches_jnp_path(mesh1, monkeypatch):
    """full_attention with REPRO_FLASH on/off agrees (S > q_chunk)."""
    from repro.core.config import AttentionConfig
    from repro.models import attention as A
    cfg = AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=16)
    d, B, S = 64, 1, 128
    p = A.init_attention(RNG, cfg, d)
    x = jax.random.normal(RNG, (B, S, d), jnp.float32)
    monkeypatch.setenv("REPRO_FLASH", "0")
    y0, _ = A.full_attention(p, x, cfg, positions=jnp.arange(S), q_chunk=32)
    monkeypatch.setenv("REPRO_FLASH", "1")
    y1, _ = A.full_attention(p, x, cfg, positions=jnp.arange(S), q_chunk=32,
                             mesh=mesh1)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=1e-4, atol=1e-5)


def test_context_parallel_flash_matches_single(mesh8):
    """Sequence-sharded (context-parallel) flash ≡ unsharded."""
    from repro.core.config import AttentionConfig
    from repro.models import attention as A
    cfg = AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=16)
    d, B, S = 64, 4, 128
    p = A.init_attention(RNG, cfg, d)
    x = jax.random.normal(RNG, (B, S, d), jnp.float32)
    y1, _ = A.full_attention(p, x, cfg, positions=jnp.arange(S), q_chunk=32)
    y8, _ = A.full_attention(p, x, cfg, positions=jnp.arange(S), q_chunk=32,
                             mesh=mesh8)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y8),
                               rtol=1e-4, atol=1e-5)
