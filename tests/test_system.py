"""System-level behaviour: multi-device training with the production
sharding rules (8 fake devices), and a mini dry-run (lower+compile)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.core.config import TrainConfig
from repro.data import SyntheticLM
from repro.launch import mesh as mesh_lib
from repro.training import make_train_step
from repro.training.train_step import init_train_state

RNG = jax.random.PRNGKey(0)


def test_sharded_train_matches_single_device(mesh1, mesh8):
    """The production sharding rules change nothing numerically."""
    cfg = configs.smoke_config("dbrx-132b").replace(dtype="float32")
    tcfg = TrainConfig(total_steps=3, warmup_steps=0)
    ds = SyntheticLM(cfg, batch=8, seq_len=16)
    b = ds.next_batch(0)

    results = {}
    for name, mesh in (("1dev", mesh1), ("8dev", mesh8)):
        state = init_train_state(RNG, cfg, tcfg)
        if name == "8dev":
            sh = mesh_lib.state_shardings(mesh, jax.eval_shape(lambda: state))
            state = jax.device_put(state, sh)
            b_sh = mesh_lib.batch_shardings(mesh, jax.eval_shape(lambda: b))
            bb = jax.device_put(b, b_sh)
        else:
            bb = b
        step = jax.jit(make_train_step(cfg, tcfg, mesh))
        state, m = step(state, bb, RNG)
        results[name] = (float(m["ce"]),
                         np.asarray(jax.device_get(state.params["final_norm"])))
    np.testing.assert_allclose(results["1dev"][0], results["8dev"][0],
                               rtol=1e-4)
    np.testing.assert_allclose(results["1dev"][1], results["8dev"][1],
                               rtol=1e-3, atol=1e-5)


def test_mini_dryrun_lowers_and_compiles(mesh8):
    """lower().compile() with sharded ShapeDtypeStructs — the same path
    the 512-device production dry-run takes."""
    cfg = configs.smoke_config("llama4-maverick-400b-a17b")
    tcfg = TrainConfig(remat="block")
    state_shapes = jax.eval_shape(
        lambda r: init_train_state(r, cfg, tcfg), jax.random.key(0))
    state = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        state_shapes, mesh_lib.state_shardings(mesh8, state_shapes))
    from repro.data.pipeline import make_batch_specs
    from repro.core.config import ShapeConfig
    shape = ShapeConfig("mini", 32, 8, "train")
    batch = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        make_batch_specs(cfg, shape),
        mesh_lib.batch_shardings(mesh8, make_batch_specs(cfg, shape)))
    rng = jax.ShapeDtypeStruct((2,), jnp.uint32,
                               sharding=NamedSharding(mesh8, P()))
    fn = make_train_step(cfg, tcfg, mesh8)

    def step(state, batch, rng_raw):
        return fn(state, batch, jax.random.wrap_key_data(rng_raw))

    compiled = jax.jit(step, donate_argnums=(0,)).lower(state, batch, rng).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):          # jax 0.4.x: one dict per device
        ca = ca[0]
    assert ca.get("flops", 0) > 0
    ma = compiled.memory_analysis()
    assert ma.temp_size_in_bytes > 0


def test_collective_parser():
    from repro.launch.dryrun import parse_collectives
    hlo = """
  %all-to-all.1 = bf16[8,1344,6144]{2,1,0} all-to-all(%x), channel_id=1, replica_groups=[16,16]<=[256], dimensions={0}
  %all-reduce.2 = f32[128]{0} all-reduce(%y), channel_id=2, replica_groups=[1,256]<=[256], to_apply=%add
  %ag = (bf16[64,32]{1,0}, bf16[64,32]{1,0}) all-gather(%a, %b), channel_id=3, replica_groups=[16,16]<=[256], dimensions={0}
"""
    out = parse_collectives(hlo)
    assert out["count"] == 3
    a2a = [o for o in out["ops"] if o["kind"] == "all-to-all"][0]
    assert a2a["group"] == 16
    assert a2a["result_bytes"] == 8 * 1344 * 6144 * 2
    ar = [o for o in out["ops"] if o["kind"] == "all-reduce"][0]
    np.testing.assert_allclose(ar["wire_bytes"], 2 * 512 * 255 / 256)
    ag = [o for o in out["ops"] if o["kind"] == "all-gather"][0]
    assert ag["result_bytes"] == 2 * 64 * 32 * 2


def test_fit_spec_drops_nondivisible():
    mesh = mesh_lib.make_smoke_mesh((2, 4))
    s = mesh_lib.fit_spec(mesh, P("data", "model"), (6, 92553))
    assert s.spec == P("data", None)
    s = mesh_lib.fit_spec(mesh, P(("data", "model"),), (8,))
    assert s.spec == P(("data", "model"))
    s = mesh_lib.fit_spec(mesh, P(("data", "model"),), (4,))
    assert s.spec == P(None)
