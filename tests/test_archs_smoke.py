"""Per-architecture smoke tests (deliverable f): reduced same-family
variants — one forward + one train step + one decode step on CPU,
asserting shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.config import TrainConfig
from repro.data import SyntheticLM
from repro.models import transformer as T
from repro.training import make_train_step
from repro.training.train_step import init_train_state

RNG = jax.random.PRNGKey(0)
B, S = 2, 16


def _inputs(cfg):
    if cfg.frontend:
        return jax.random.normal(RNG, (B, S, cfg.d_model), jnp.float32) * 0.02
    return jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)


@pytest.mark.parametrize("arch", configs.ASSIGNED)
def test_forward_shapes_no_nan(arch, mesh1):
    cfg = configs.smoke_config(arch)
    p = T.init_model(RNG, cfg)
    h, aux, _ = T.forward(p, _inputs(cfg), cfg, mesh=mesh1)
    logits = T.logits_from_hidden(p, cfg, h, mesh1)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", configs.ASSIGNED)
def test_one_train_step(arch, mesh1):
    cfg = configs.smoke_config(arch)
    tcfg = TrainConfig(total_steps=2, warmup_steps=1)
    state = init_train_state(RNG, cfg, tcfg)
    ds = SyntheticLM(cfg, batch=B, seq_len=S)
    step = jax.jit(make_train_step(cfg, tcfg, mesh1))
    state, m = step(state, ds.next_batch(0), RNG)
    assert bool(jnp.isfinite(m["loss"])), (arch, m)
    assert int(state.step) == 1
    # params actually changed
    before = init_train_state(RNG, cfg, tcfg).params["final_norm"]
    assert float(jnp.max(jnp.abs(state.params["final_norm"] - before))) >= 0


@pytest.mark.parametrize("arch", [a for a in configs.ASSIGNED
                                  if configs.get_config(a).has_decode])
def test_one_decode_step(arch, mesh1):
    cfg = configs.smoke_config(arch)
    p = T.init_model(RNG, cfg)
    caches = T.init_caches(cfg, B, 32)
    _, _, caches = T.forward(p, _inputs(cfg), cfg, mesh=mesh1, caches=caches,
                             collect_caches=True)
    tok = (jax.random.randint(RNG, (B, 1), 0, cfg.vocab_size)
           if cfg.frontend is None else
           jax.random.normal(RNG, (B, 1, cfg.d_model), jnp.float32) * 0.02)
    lg, caches = T.decode_step(p, tok, caches, cfg, mesh=mesh1)
    assert lg.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(lg)))


def test_encoder_only_has_no_decode():
    cfg = configs.get_config("hubert-xlarge")
    assert not cfg.has_decode


def test_long_context_eligibility_matrix():
    """DESIGN.md §skips: exactly these archs run long_500k."""
    from repro.launch.dryrun import eligible
    runs = {a for a in configs.ASSIGNED if eligible(a, "long_500k") is None}
    assert runs == {"rwkv6-1.6b", "h2o-danube-3-4b", "zamba2-7b", "gemma2-9b"}
    # and decode_32k skips exactly the encoder-only arch
    runs32 = {a for a in configs.ASSIGNED if eligible(a, "decode_32k") is None}
    assert configs.ASSIGNED and runs32 == set(configs.ASSIGNED) - {"hubert-xlarge"}


@pytest.mark.parametrize("arch", configs.ASSIGNED)
def test_exact_config_matches_assignment(arch):
    """The full configs carry the exact assigned dimensions."""
    expect = {
        "rwkv6-1.6b": (24, 2048, 7168, 65536),
        "h2o-danube-3-4b": (24, 3840, 10240, 32000),
        "yi-6b": (32, 4096, 11008, 64000),
        "llama4-maverick-400b-a17b": (48, 5120, 8192, 202048),
        "dbrx-132b": (40, 6144, 10752, 100352),
        "internvl2-2b": (24, 2048, 8192, 92553),
        "zamba2-7b": (81, 3584, 14336, 32000),
        "gemma2-9b": (42, 3584, 14336, 256000),
        "hubert-xlarge": (48, 1280, 5120, 504),
        "starcoder2-3b": (30, 3072, 12288, 49152),
    }[arch]
    cfg = configs.get_config(arch)
    assert (cfg.num_layers, cfg.d_model, cfg.d_ff, cfg.vocab_size) == expect
    if arch == "llama4-maverick-400b-a17b":
        assert cfg.moe.num_experts == 128 and cfg.moe.top_k == 1
    if arch == "dbrx-132b":
        assert cfg.moe.num_experts == 16 and cfg.moe.top_k == 4
    if arch == "zamba2-7b":
        assert cfg.ssm.d_state == 64
