"""The graph-invariant linter (``repro.analysis``).

Coverage contract (the ROADMAP "new graph invariant ⇒ new rule +
known-bad test" convention, applied to the shipped rules themselves):
every registered rule has a KNOWN-BAD case here that makes it fire, and
the full dispatch config matrix runs CLEAN at error level in-process
(the negative control proving the rules stay quiet on healthy graphs).
Also covers the structured walker (loop depth, sub-jaxpr recursion,
structural paths), the HLO-side graph incl. the f8 dtype table, and the
``python -m repro.analysis.lint`` CLI (bad-config cells become findings
+ exit 1, never tracebacks; the full-matrix subprocess run is
slow-marked and diffs against the committed ``LINT_moe.json``).
"""
import json
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro import analysis
from repro.analysis import lint as lint_cli
from repro.core import moe
from repro.core.config import MoEConfig
from repro.launch import hlo_analysis as H

RNG = jax.random.PRNGKey(3)
REPO = pathlib.Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# the structured walker
# ---------------------------------------------------------------------------

def test_walker_recurses_into_loop_bodies_with_depth_and_trip():
    def f(x):
        def body(c, t):
            return c + jnp.dot(t, t), ()
        c, _ = jax.lax.scan(body, jnp.zeros(()), x)
        return c

    g = analysis.trace_graph(f, jnp.ones((5, 3)))
    dots = g.find("dot_general")
    assert len(dots) == 1
    site = dots[0]
    assert site.loop_depth == 1
    assert site.trip == 5                      # scan length propagated
    assert site.describe().endswith("scan/dot_general")


def test_walker_recurses_into_cond_branches_without_loop_depth():
    def f(x, flag):
        return jax.lax.cond(flag, lambda v: jnp.dot(v, v), lambda v: v * 2.0,
                            x)

    g = analysis.trace_graph(f, jnp.ones((3, 3)), True)
    assert g.count("dot_general") == 1
    assert all(s.loop_depth == 0 for s in g.find("dot_general"))


def test_trace_graph_context_and_primitives_counter():
    g = analysis.trace_graph(lambda a, b: jnp.dot(a, b) + 1.0,
                             jnp.ones((2, 2)), jnp.ones((2, 2)),
                             context={"label": "unit"})
    assert g.label == "unit"
    assert g.primitives()["dot_general"] == 1


# ---------------------------------------------------------------------------
# per-rule known-bad graphs (each rule must FIRE somewhere)
# ---------------------------------------------------------------------------

def test_known_bad_collective_in_loop_jaxpr(mesh_ep4):
    """The PR 5 anti-pattern: pipelining via fori_loop/scan folds every
    exchange into ONE loop-body collective."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def layer(x):          # x (4, d) local block, scans 4 "chunks"
        def body(c, t):
            return c + jax.lax.psum(t, "model"), ()
        c, _ = jax.lax.scan(body, jnp.zeros(x.shape[-1]), x)
        return c

    fn = shard_map(layer, mesh=mesh_ep4, in_specs=P(None, None),
                   out_specs=P(None), check_rep=False)
    g = analysis.trace_graph(fn, jnp.ones((4, 8)))
    findings = analysis.run_rule("collective-in-loop", g)
    assert len(findings) == 1
    f = findings[0]
    assert f.level == "error"
    assert "psum" in f.message and "loop body" in f.message
    assert "scan" in f.location                # structural path, not offset
    # the same graph is clean when the loop is explicitly allowed
    g.context["allow_loop_collectives"] = True
    assert analysis.run_rule("collective-in-loop", g) == []


def test_known_bad_overlap_chunk_count(mesh_ep4):
    """An unchunked (P=1) pipeline linted against a P=4 contract must
    miss on both the equation count and the payload windows."""
    mk = lambda P: MoEConfig(num_experts=8, dispatch="grouped", gate="topk",
                             top_k=2, capacity_factor=8.0, overlap_chunks=P)
    cfg1 = mk(1)
    p = moe.init_moe_params(RNG, cfg1, 32, 64, 8, act="swiglu",
                            dtype=jnp.float32)
    x = jax.random.normal(RNG, (4, 16, 32))
    g = analysis.trace_graph(
        lambda p_, v: moe.sharded_moe_apply(mesh_ep4, cfg1, p_, v,
                                            num_experts=8, act="swiglu"),
        p, x, context={"cfg": mk(4), "model_size": 4, "tokens_per_shard": 16,
                       "d_model": 32, "direction": "fwd"})
    findings = analysis.run_rule("overlap-chunk-count", g)
    assert len(findings) == 2, findings
    count_f, payload_f = findings
    assert "12 all_to_all equations, traced 3" in count_f.message
    assert "(4, 8, 32)" in payload_f.message   # (M, B/P, d) window


def test_known_bad_tuned_plan_consistency(mesh_ep4):
    """A flat/P=1 graph linted against an ``"auto"``-knob contract: the
    tuner resolves hierarchical (stages=2) for this tiny cell, so the
    traced graph misses on both the equation count (3 vs 5) and the
    payload-window count — "auto" silently changed a traced graph shape,
    the exact drift the rule exists to catch."""
    import dataclasses
    concrete = MoEConfig(num_experts=8, dispatch="grouped", gate="topk",
                         top_k=2, capacity_factor=8.0, a2a="flat",
                         overlap_chunks=1)
    auto = dataclasses.replace(concrete, a2a="auto", overlap_chunks="auto",
                               grouped_block_m="auto",
                               grouped_ep_bound_factor="auto")
    p = moe.init_moe_params(RNG, concrete, 32, 64, 8, act="swiglu",
                            dtype=jnp.float32)
    x = jax.random.normal(RNG, (4, 16, 32))
    ctx = {"cfg": auto, "model_size": 4, "tokens_per_shard": 16,
           "d_model": 32, "direction": "fwd", "dtype": jnp.float32}
    g = analysis.trace_graph(
        lambda p_, v: moe.sharded_moe_apply(mesh_ep4, concrete, p_, v,
                                            num_experts=8, act="swiglu"),
        p, x, context=ctx)
    findings = analysis.run_rule("tuned-plan-consistency", g)
    assert len(findings) == 2, findings
    count_f, payload_f = findings
    assert "a2a='hierarchical'" in count_f.message
    assert "expects 5 all_to_all equations, traced 3" in count_f.message
    assert payload_f.level == "error"
    # positive control: the graph traced from the SAME auto config is
    # consistent with the plan the rule resolves
    g_auto = analysis.trace_graph(
        lambda p_, v: moe.sharded_moe_apply(mesh_ep4, auto, p_, v,
                                            num_experts=8, act="swiglu"),
        p, x, context=ctx)
    assert analysis.run_rule("tuned-plan-consistency", g_auto) == []
    # concrete-config cells stay owned by overlap-chunk-count
    g.context["cfg"] = concrete
    assert analysis.run_rule("tuned-plan-consistency", g) == []


def test_known_bad_payload_dtype(mesh_ep4):
    """PR 10, both failure directions.  A full-width (f32) exchange
    linted against an ``payload_dtype="int8"`` contract means the
    quantize/dequantize pair was dropped; an int8 exchange linted
    against a payload-unset contract means low-precision wire dtypes
    are leaking where the config promises the compute dtype."""
    import dataclasses
    full = MoEConfig(num_experts=8, dispatch="grouped", gate="topk",
                     top_k=2, capacity_factor=8.0)
    quant = dataclasses.replace(full, payload_dtype="int8")
    p = moe.init_moe_params(RNG, full, 32, 64, 8, act="swiglu",
                            dtype=jnp.float32)
    x = jax.random.normal(RNG, (4, 16, 32))
    ctx = lambda cfg: {"cfg": cfg, "model_size": 4, "tokens_per_shard": 16,
                       "d_model": 32, "direction": "fwd",
                       "dtype": jnp.float32}
    trace = lambda cfg, c: analysis.trace_graph(
        lambda p_, v: moe.sharded_moe_apply(mesh_ep4, cfg, p_, v,
                                            num_experts=8, act="swiglu"),
        p, x, context=ctx(c))

    # quantization promised but never applied: every payload window is
    # still full-width on the wire
    findings = analysis.run_rule("payload-dtype", trace(full, quant))
    assert findings and all(f.level == "error" for f in findings)
    assert all("quantize/dequantize pair" in f.message for f in findings)
    assert any("int8" in f.message and "float32" in f.message
               for f in findings)
    # the reverse leak: int8 on a wire the config says is full-width
    findings = analysis.run_rule("payload-dtype", trace(quant, full))
    assert findings and all("int8" in f.message for f in findings)
    # positive controls: graph and contract agree, both ways
    assert analysis.run_rule("payload-dtype", trace(quant, quant)) == []
    assert analysis.run_rule("payload-dtype", trace(full, full)) == []


def test_known_bad_no_recompute_backward():
    """Differentiating raw ``lax.ragged_dot`` re-runs it in the VJP —
    the exact recompute the custom_vjp kernels exist to avoid."""
    lhs = jax.random.normal(RNG, (32, 8))
    rhs = jax.random.normal(RNG, (4, 8, 8))
    sizes = jnp.array([10, 6, 0, 16], jnp.int32)
    cfg = MoEConfig(num_experts=4, dispatch="grouped", gate="topk", top_k=2,
                    capacity_factor=8.0, use_pallas_gate=True)
    g = analysis.trace_graph(
        jax.grad(lambda l: jnp.sum(jax.lax.ragged_dot(l, rhs, sizes) ** 2)),
        lhs, context={"cfg": cfg, "direction": "grad"})
    findings = analysis.run_rule("no-recompute-backward", g)
    assert findings and all(f.level == "error" for f in findings)
    assert any("ragged_dot" in f.location for f in findings)
    # the gate: a forward graph under the same config is out of scope
    g.context["direction"] = "fwd"
    assert analysis.run_rule("no-recompute-backward", g) == []


def test_known_bad_dtype_leak():
    """An f32 operand against a bf16 one traces without complaint — the
    rule is the only thing that notices the missing cast."""
    a32 = jnp.ones((4, 8), jnp.float32)
    b16 = jnp.ones((8, 4), jnp.bfloat16)
    dot = lambda a, b: jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())))
    bad = analysis.trace_graph(dot, a32, b16)
    findings = analysis.run_rule("dtype-leak", bad)
    assert len(findings) == 1
    assert "bfloat16" in findings[0].message
    assert "float32" in findings[0].message
    # f32 ACCUMULATION via preferred_element_type is fine by design
    ok = analysis.trace_graph(
        lambda a, b: jax.lax.dot_general(
            a, b, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32),
        b16.T, b16)
    assert analysis.run_rule("dtype-leak", ok) == []
    # integer group_sizes next to float operands are exempt
    sizes = jnp.array([2, 2], jnp.int32)
    ragged = analysis.trace_graph(
        lambda l, r: jax.lax.ragged_dot(l, r, sizes),
        jnp.ones((4, 8), jnp.bfloat16), jnp.ones((2, 8, 4), jnp.bfloat16))
    assert analysis.run_rule("dtype-leak", ragged) == []


def test_known_bad_donation_alias():
    z = jnp.zeros((), jnp.int32)
    findings = analysis.lint_probe(donated={"a": z, "b": z, "c": z + 1})
    assert len(findings) == 1
    f = findings[0]
    assert f.rule == "donation-alias" and f.level == "error"
    assert "'a'" in f.location and "'b'" in f.location
    # distinct buffers: clean
    ok = {"a": jnp.zeros((), jnp.int32), "b": jnp.zeros((), jnp.int32)}
    assert analysis.lint_probe(donated=ok) == []


def test_real_train_state_donation_is_alias_free():
    """The probe the CLI runs: a freshly initialized TrainState (the
    pytree ``make_train_step`` donates) has no shared buffers."""
    from repro import configs
    from repro.core.config import TrainConfig
    from repro.training.train_step import init_train_state

    cfg = configs.smoke_config("dbrx-132b").replace(dtype="float32")
    state = init_train_state(jax.random.PRNGKey(0), cfg, TrainConfig())
    assert analysis.lint_probe(donated=state) == []


def test_known_bad_retrace_budget():
    counts = {("decode", "dbrx", 1, 32): 3, ("prefill", "dbrx", 1, 32): 1}
    findings = analysis.lint_probe(trace_counts=counts)
    assert len(findings) == 1
    assert findings[0].rule == "retrace-budget"
    assert "3x" in findings[0].message
    assert analysis.lint_probe(trace_counts=counts, budget=3) == []


def test_known_bad_config_invalid():
    findings = analysis.lint_probe(config_error="P does not divide B",
                                   label="grouped/ep4/flat/P5")
    assert len(findings) == 1
    f = findings[0]
    assert f.rule == "config-invalid" and f.level == "error"
    assert f.location == "grouped/ep4/flat/P5"
    assert "does not divide" in f.message


# ---------------------------------------------------------------------------
# HLO-side graph + the f8 dtype table (launch/hlo_analysis.py)
# ---------------------------------------------------------------------------

_F8_HLO = """\
HloModule synth

%body (p: (s32[], f8e4m3fn[4,16])) -> (s32[], f8e4m3fn[4,16]) {
  %p = (s32[], f8e4m3fn[4,16]) parameter(0)
  %it = s32[] get-tuple-element((s32[], f8e4m3fn[4,16]) %p), index=0
  %one = s32[] constant(1)
  %next = s32[] add(s32[] %it, s32[] %one)
  %buf = f8e4m3fn[4,16] get-tuple-element((s32[], f8e4m3fn[4,16]) %p), index=1
  %xchg = f8e4m3fn[4,16] all-to-all(f8e4m3fn[4,16] %buf), replica_groups=[1,4]
  ROOT %out = (s32[], f8e4m3fn[4,16]) tuple(s32[] %next, f8e4m3fn[4,16] %xchg)
}

%cond (p: (s32[], f8e4m3fn[4,16])) -> pred[] {
  %p = (s32[], f8e4m3fn[4,16]) parameter(0)
  %it = s32[] get-tuple-element((s32[], f8e4m3fn[4,16]) %p), index=0
  %n = s32[] constant(3)
  ROOT %lt = pred[] compare(s32[] %it, s32[] %n), direction=LT
}

ENTRY %main (arg: f8e4m3fn[4,16], wide: f8e4m3fnuz[8]) -> f8e4m3fn[4,16] {
  %arg = f8e4m3fn[4,16] parameter(0)
  %wide = f8e4m3fnuz[8] parameter(1)
  %zero = s32[] constant(0)
  %init = (s32[], f8e4m3fn[4,16]) tuple(s32[] %zero, f8e4m3fn[4,16] %arg)
  %w = (s32[], f8e4m3fn[4,16]) while((s32[], f8e4m3fn[4,16]) %init), \
condition=%cond, body=%body
  ROOT %res = f8e4m3fn[4,16] get-tuple-element((s32[], f8e4m3fn[4,16]) %w), \
index=1
}
"""


def test_hlo_parser_sizes_f8_ops():
    """Satellite: the roofline's dtype table covers the f8 families, so
    a quantized exchange buffer keeps its byte counts."""
    comps, shapes = H.parse_module(_F8_HLO)
    a2a = [op for op in comps["body"] if op.kind == "all-to-all"]
    assert len(a2a) == 1
    assert a2a[0].result_bytes == 4 * 16 * 1          # 1 byte/elem, not 0/4
    assert a2a[0].result_dims == [("f8e4m3fn", [4, 16])]
    # longest-first alternation: f8e4m3fnuz must not parse as
    # f8e4m3fn + stray text (8 bytes, one dim of 8)
    assert shapes["wide"] == (8, [("f8e4m3fnuz", [8])])
    for dt in ("f8e4m3fn", "f8e5m2", "f8e4m3b11fnuz"):
        assert H._DTYPE_BYTES[dt] == 1


def test_known_bad_collective_in_loop_hlo():
    """HLO side of the rule: the while-wrapped all-to-all above executes
    every iteration (×3 trip) — exactly what the jaxpr-side rule cannot
    see once XLA re-schedules."""
    g = analysis.HloGraph(_F8_HLO, context={"label": "synth"})
    assert g.entry == "main"
    assert g.in_loop["body"] and g.in_loop["cond"]
    assert g.mult["body"] == 3.0                      # trip from %cond
    findings = analysis.lint_hlo(g)
    assert [f.rule for f in findings] == ["collective-in-loop"]
    assert "while body" in findings[0].message
    assert findings[0].location == "body/all-to-all"


def test_hlo_graph_clean_when_collective_at_top_level():
    txt = """\
HloModule ok

ENTRY %main (arg: bf16[4,16]) -> bf16[4,16] {
  %arg = bf16[4,16] parameter(0)
  ROOT %xchg = bf16[4,16] all-to-all(bf16[4,16] %arg), replica_groups=[1,4]
}
"""
    g = analysis.HloGraph(txt)
    assert g.count("all-to-all") == 1
    assert analysis.lint_hlo(g) == []
    with pytest.raises(ValueError, match="no computations"):
        analysis.HloGraph("not hlo at all")


# ---------------------------------------------------------------------------
# the clean matrix (negative control: every rule quiet on healthy graphs)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cell", lint_cli.matrix_cells())
def test_matrix_cell_lints_clean(cell):
    assert lint_cli.lint_cell(cell) == []


def test_matrix_covers_the_contracted_shapes():
    cells = lint_cli.matrix_cells()
    assert len(cells) == len(set(cells))
    for want in ("sort/r1/flat/P1", "grouped/ep4/hier/P4",
                 "grouped/ep2tp2/flat/P2", "grouped/tp2/flat/P4",
                 "decode/ep4/grouped/P1",
                 # PR 9: every mesh gets an all-knobs-"auto" cell, plus
                 # the auto decode cell (step-BUILD-time resolution)
                 "grouped/r1/auto/Pauto", "grouped/ep4/auto/Pauto",
                 "grouped/tp2/auto/Pauto", "grouped/ep2tp2/auto/Pauto",
                 "decode/ep4/grouped/Pauto",
                 # PR 10: quantized-wire cells (int8 + one fp8) across
                 # flat/hier, P=1/2, EP and EP×TP, plus a decode cell
                 "grouped/ep4/flat/P1/int8", "grouped/ep4/flat/P2/int8",
                 "grouped/ep4/hier/P1/float8_e4m3fn",
                 "grouped/ep2tp2/flat/P2/int8",
                 "decode/ep4/grouped/P1/int8"):
        assert want in cells
    # hier cells only exist where a model axis exists to factorize
    assert not any("/r1/hier/" in c or "/tp2/hier/" in c for c in cells)


def test_lint_cell_rejects_unknown_vocabulary():
    with pytest.raises(ValueError, match="bad lint cell"):
        lint_cli.parse_cell("grouped/ep4/flat")
    with pytest.raises(ValueError, match="bad lint cell"):
        lint_cli.parse_cell("groped/ep4/flat/P2")
    with pytest.raises(ValueError, match="bad lint cell"):
        lint_cli.parse_cell("grouped/ep4/flat/Px")


def test_bad_overlap_bound_is_a_finding_not_a_traceback():
    """Satellite: the validator error paths surface as findings through
    the same lint_cell the CLI drives."""
    for cell in ("grouped/ep4/flat/P5", "decode/ep4/grouped/P3"):
        findings = lint_cli.lint_cell(cell)
        assert [f.rule for f in findings] == ["config-invalid"], cell
        assert "overlap_chunks" in findings[0].message


# ---------------------------------------------------------------------------
# the CLI (subprocess; report schema diffable like BENCH_moe.json)
# ---------------------------------------------------------------------------

def _run_cli(*extra):
    env = {**os.environ, "PYTHONPATH": "src",
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8"}
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", *extra],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=900)


def test_cli_bad_config_exits_nonzero_with_report(tmp_path):
    out = tmp_path / "lint.json"
    r = _run_cli("--config", "decode/ep4/grouped/P3", "--json", str(out))
    assert r.returncode == 1, r.stdout + r.stderr
    assert "Traceback" not in r.stderr
    assert "config-invalid" in r.stdout
    report = json.loads(out.read_text())
    assert report["schema"] == lint_cli.SCHEMA
    assert report["summary"]["error"] == 1
    [finding] = report["findings"]
    assert finding["rule"] == "config-invalid"
    assert finding["config"] == "decode/ep4/grouped/P3"


def test_cli_unknown_cell_is_an_argparse_error(tmp_path):
    r = _run_cli("--config", "grouped/nope/flat/P2",
                 "--json", str(tmp_path / "l.json"))
    assert r.returncode == 2, r.stdout + r.stderr
    assert "bad lint cell" in r.stderr


@pytest.mark.slow
def test_cli_full_matrix_clean_and_matches_committed_report(tmp_path):
    """The acceptance run: full matrix + HLO pass + probes, exit 0, and
    the scratch report agrees with the committed LINT_moe.json on
    schema, rules, matrix, and finding count."""
    out = tmp_path / "lint.json"
    r = _run_cli("--json", str(out))
    assert r.returncode == 0, r.stdout + r.stderr
    fresh = json.loads(out.read_text())
    committed = json.loads((REPO / "LINT_moe.json").read_text())
    assert fresh["schema"] == committed["schema"]
    assert fresh["matrix"] == committed["matrix"]
    assert sorted(fresh["rules"]) == sorted(committed["rules"])
    assert fresh["findings"] == committed["findings"] == []
    assert fresh["summary"]["error"] == 0
