"""Workload-replay harness: seeded determinism, replay accounting, and
router skew — the traffic layer feeding ``benchmarks/bench_traffic.py``."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as T
from repro.serving import Request, SlotServer
from repro.serving.traffic import (TrafficConfig, replay, skew_router,
                                   synthesize_workload)

RNG = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# workload synthesis
# ---------------------------------------------------------------------------

def _workload_sig(wl):
    return [(at, int(r.uid), np.asarray(r.prompt).tolist(), r.max_new)
            for at, r in wl]


@pytest.mark.parametrize("arrival", ["poisson", "bursty"])
def test_workload_deterministic_per_seed(arrival):
    cfg = configs.smoke_config("dbrx-132b")
    tc = TrafficConfig(num_requests=10, arrival=arrival, seed=5)
    a = synthesize_workload(tc, cfg)
    b = synthesize_workload(tc, cfg)
    assert _workload_sig(a) == _workload_sig(b)
    c = synthesize_workload(TrafficConfig(num_requests=10, arrival=arrival,
                                          seed=6), cfg)
    assert _workload_sig(a) != _workload_sig(c)
    assert len(a) == 10
    assert all(at <= bt for (at, _), (bt, _) in zip(a, a[1:]))
    for _, r in a:
        assert r.prompt.shape[-1] in tc.prompt_lens
        assert r.max_new in tc.max_new_choices
        toks = np.asarray(r.prompt)
        assert toks.min() >= 0 and toks.max() < cfg.vocab_size


def test_bursty_arrivals_come_in_bursts():
    cfg = configs.smoke_config("dbrx-132b")
    wl = synthesize_workload(
        TrafficConfig(num_requests=10, arrival="bursty", burst_size=4,
                      burst_every=8), cfg)
    arrivals = [at for at, _ in wl]
    assert arrivals == [0] * 4 + [8] * 4 + [16] * 2


def test_traffic_config_validation():
    with pytest.raises(ValueError, match="arrival"):
        TrafficConfig(arrival="uniform")
    with pytest.raises(ValueError, match="num_requests"):
        TrafficConfig(num_requests=0)


# ---------------------------------------------------------------------------
# router skew
# ---------------------------------------------------------------------------

def test_skew_router_biases_one_expert_and_copies():
    cfg = configs.smoke_config("dbrx-132b").replace(dtype="float32")
    params = T.init_model(RNG, cfg)
    before = jax.tree.map(np.asarray, params)
    skewed = skew_router(params, bias=16.0, expert=1)
    # original untouched
    for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(
            jax.tree.map(np.asarray, params))):
        np.testing.assert_array_equal(a, b)
    changed = 0
    for blk_old, blk_new in zip(params["blocks"], skewed["blocks"]):
        if not (isinstance(blk_old, dict) and "moe" in blk_old):
            continue
        gw_old = np.asarray(blk_old["moe"]["gate_w"])
        gw_new = np.asarray(blk_new["moe"]["gate_w"])
        np.testing.assert_allclose(gw_new[..., 1], gw_old[..., 1] + 16.0,
                                   rtol=1e-6)
        mask = np.ones(gw_old.shape[-1], bool)
        mask[1] = False
        np.testing.assert_array_equal(gw_new[..., mask], gw_old[..., mask])
        # bias is decisive at init scale: the skewed column wins argmax
        assert (gw_new.argmax(-1) == 1).all()
        changed += 1
    assert changed > 0, "no MoE router found to skew"


# ---------------------------------------------------------------------------
# replay
# ---------------------------------------------------------------------------

def _replay_env():
    cfg = configs.smoke_config("dbrx-132b").replace(dtype="float32")
    params = T.init_model(RNG, cfg)
    return cfg, params


def test_replay_drains_everything_and_reports(mesh1):
    cfg, params = _replay_env()
    tc = TrafficConfig(num_requests=6, arrival="poisson", rate=0.7, seed=3)
    srv = SlotServer(cfg, params, slots=2, cache_len=20, mesh=mesh1,
                     dispatch="grouped", queue_limit=8)
    rep = replay(srv, synthesize_workload(tc, cfg))
    assert len(rep.statuses) == 6
    assert (rep.completed + rep.rejected + rep.failed
            + rep.evicted) == 6
    assert rep.completed > 0 and rep.tokens_out > 0
    assert rep.decode_steps > 0 and not srv.active and not srv.queue
    assert 0.0 < rep.slot_utilization <= 1.0
    assert rep.p99_per_token_s >= rep.p50_per_token_s > 0.0
    assert rep.p99_first_token_s >= rep.p50_first_token_s > 0.0
    s = rep.summary()
    assert "completed=6" in s and "util=" in s


def test_replay_workload_shape_is_machine_independent(mesh1):
    """Statuses, token counts and decode-step count are functions of the
    seed alone — two replays of the same workload agree exactly (only
    the wall-clock latencies may differ)."""
    cfg, params = _replay_env()
    tc = TrafficConfig(num_requests=5, arrival="bursty", burst_size=3,
                       burst_every=4, seed=9)
    outs = []
    for _ in range(2):
        srv = SlotServer(cfg, params, slots=2, cache_len=20, mesh=mesh1,
                         dispatch="grouped")
        rep = replay(srv, synthesize_workload(tc, cfg))
        outs.append((rep.statuses, rep.tokens_out, rep.decode_steps,
                     rep.slot_utilization))
    assert outs[0] == outs[1]


def test_replay_counts_rejections(mesh1):
    """An inadmissible request (prompt longer than the cache) shows up as
    a rejection in the report, not a hang or a crash."""
    cfg, params = _replay_env()
    srv = SlotServer(cfg, params, slots=1, cache_len=8, mesh=mesh1,
                     dispatch="grouped")
    wl = [(0, Request(uid=0, prompt=jnp.zeros((4,), jnp.int32), max_new=2)),
          (0, Request(uid=1, prompt=jnp.zeros((32,), jnp.int32), max_new=2))]
    rep = replay(srv, wl)
    assert rep.rejected == 1 and rep.completed == 1
    assert rep.statuses == {0: "ok", 1: "rejected"}
    assert not math.isnan(rep.p50_per_token_s)
