"""Overlapped (chunked, double-buffered) grouped AllToAll ↔ expert-compute
pipeline (``MoEConfig.overlap_chunks``).

Acceptance properties: ``overlap_chunks > 1`` is numerically equivalent
to the unchunked grouped path — forward AND gradients, per-dtype
tolerances — across grouped-EP × expert-TP × {flat, hierarchical}, the
jaxpr witnesses that P chunked all-to-alls are actually emitted (a
fori_loop would fold them into one loop-body collective), and the
chunk-count / chunk-bound arithmetic holds standalone.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import analysis
from repro.core import capacity, layout, moe
from repro.core.config import MoEConfig

RNG = jax.random.PRNGKey(11)
D = 32
E = 8


def _cfg(P=1, **kw):
    kw.setdefault("gate", "topk")
    kw.setdefault("top_k", 2)
    kw.setdefault("capacity_factor", 8.0)
    return MoEConfig(num_experts=E, dispatch="grouped", overlap_chunks=P,
                     **kw)


def _params(cfg, dtype=jnp.float32):
    return moe.init_moe_params(RNG, cfg, D, 64, cfg.num_experts,
                               act="swiglu", dtype=dtype)


def _apply(mesh, cfg, params, x, tp=None):
    return jax.jit(lambda p, v: moe.sharded_moe_apply(
        mesh, cfg, p, v, num_experts=cfg.num_experts, act="swiglu",
        expert_tp_axis=tp))(params, x)


# ---------------------------------------------------------------------------
# chunk arithmetic (no collectives)
# ---------------------------------------------------------------------------

def test_grouped_chunk_counts_window_clip():
    """Windows partition the counts: each window's rows are the overlap
    of the packed live prefix with the window, and they sum back to the
    unchunked count matrix exactly."""
    counts = jnp.array([[3, 0, 5], [0, 0, 0], [7, 1, 0], [2, 2, 2]],
                       jnp.int32)                       # rows sum ≤ 8
    out = np.asarray(layout.grouped_chunk_counts(counts, 8, 4))  # Bc = 2
    assert out.shape == (4, 4, 3)
    np.testing.assert_array_equal(out.sum(axis=0), np.asarray(counts))
    assert (out.sum(axis=2) <= 2).all()                 # per-window bound
    # row 0: live rows are e0:[0,3), e2:[3,8) → windows [2,0,0],[1,0,1],
    # [0,0,2],[0,0,2]
    np.testing.assert_array_equal(
        out[:, 0], [[2, 0, 0], [1, 0, 1], [0, 0, 2], [0, 0, 2]])
    # an empty segment contributes nothing anywhere
    assert (out[:, 1] == 0).all()
    # a window past the live prefix is all-zero (row 2 lives in [0, 8)...
    # row 3 has 6 live rows: window 3 = [6, 8) is empty)
    np.testing.assert_array_equal(out[3, 3], [0, 0, 0])


def test_grouped_chunk_counts_windows_obey_receive_map_contract():
    """Per-window receive maps at bound Bc reassemble the unchunked
    expert-major order: total group sizes match the unchunked maps."""
    rs = np.random.RandomState(3)
    counts = jnp.asarray(rs.randint(0, 4, (4, 2)).astype(np.int32))
    B, P = 16, 4
    _, _, sizes_full = layout.grouped_ep_receive_maps(counts, B)
    per = layout.grouped_chunk_counts(counts, B, P)
    sizes_sum = 0
    for i in range(P):
        _, _, s = layout.grouped_ep_receive_maps(per[i], B // P)
        sizes_sum = sizes_sum + np.asarray(s)
    np.testing.assert_array_equal(sizes_sum, np.asarray(sizes_full))


def test_grouped_overlap_chunk_bound_validates():
    cfg = _cfg(P=3)
    with pytest.raises(ValueError, match="overlap_chunks=3"):
        capacity.grouped_overlap_chunk_bound(cfg, 32)
    assert capacity.grouped_overlap_chunk_bound(_cfg(P=4), 32) == 8
    assert capacity.grouped_overlap_chunk_bound(_cfg(P=1), 33) == 33


# ---------------------------------------------------------------------------
# config / entry-point validation
# ---------------------------------------------------------------------------

def test_config_rejects_bad_overlap_chunks():
    with pytest.raises(ValueError, match="overlap_chunks"):
        MoEConfig(num_experts=E, overlap_chunks=0)


def test_overlap_requires_grouped_dispatch(mesh1):
    cfg = MoEConfig(num_experts=E, dispatch="sort", overlap_chunks=2)
    p = _params(cfg)
    x = jax.random.normal(RNG, (4, 16, D))
    with pytest.raises(ValueError, match="overlap_chunks.*grouped"):
        moe.sharded_moe_apply(mesh1, cfg, p, x, num_experts=E)


def test_overlap_requires_divisible_bound(mesh_ep4):
    """T_local=16 · K=2 → B=32; P=5 does not divide it — the error names
    the config field instead of a shape assert deep in the trace."""
    cfg = _cfg(P=5)
    p = _params(cfg)
    x = jax.random.normal(RNG, (4, 16, D))
    with pytest.raises(ValueError, match="overlap_chunks=5"):
        moe.sharded_moe_apply(mesh_ep4, cfg, p, x, num_experts=E)


# ---------------------------------------------------------------------------
# numerical equivalence: chunked ≡ unchunked
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("a2a,inner", [("flat", 1), ("hierarchical", 2)])
@pytest.mark.parametrize("P", [2, 4])
def test_overlap_matches_unchunked_ep(mesh_ep4, a2a, inner, P):
    x = jax.random.normal(RNG, (4, 16, D))
    p = _params(_cfg())
    y1, aux1, m1 = _apply(mesh_ep4, _cfg(a2a=a2a, a2a_inner=inner), p, x)
    yp, auxp, mp = _apply(mesh_ep4, _cfg(P, a2a=a2a, a2a_inner=inner), p, x)
    np.testing.assert_allclose(np.asarray(yp), np.asarray(y1),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(float(auxp), float(aux1), rtol=1e-6)
    np.testing.assert_allclose(float(mp["expert_load_max"]),
                               float(m1["expert_load_max"]), rtol=1e-6)


def test_overlap_matches_unchunked_single_rank(mesh1):
    """No collectives at all: the pipeline degenerates to a chunked
    grouped FFN and must still reproduce the serial output."""
    x = jax.random.normal(RNG, (4, 16, D))
    p = _params(_cfg())
    y1, _, _ = _apply(mesh1, _cfg(), p, x)
    yp, _, _ = _apply(mesh1, _cfg(4), p, x)
    np.testing.assert_allclose(np.asarray(yp), np.asarray(y1),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("dtype,rtol,atol", [
    (jnp.float32, 1e-6, 1e-6), (jnp.bfloat16, 2e-2, 2e-2)])
def test_overlap_gradients_match_unchunked(mesh_ep4, dtype, rtol, atol):
    """Backward through the unrolled pipeline (the existing custom_vjp
    kernels, P windows of them) ≡ the serial backward, per dtype."""
    x = jax.random.normal(RNG, (4, 16, D), dtype)
    p = _params(_cfg(), dtype=dtype)

    def grad_fn(cfg):
        def loss(p, v):
            y, aux, _ = moe.sharded_moe_apply(
                mesh_ep4, cfg, p, v, num_experts=E, act="swiglu")
            return jnp.sum(y.astype(jnp.float32) ** 2) + aux
        return jax.jit(jax.value_and_grad(loss))

    l1, g1 = grad_fn(_cfg())(p, x)
    lp, gp = grad_fn(_cfg(2))(p, x)
    np.testing.assert_allclose(float(lp), float(l1), rtol=max(rtol, 1e-6))
    for k in p:
        np.testing.assert_allclose(np.asarray(gp[k], np.float32),
                                   np.asarray(g1[k], np.float32),
                                   rtol=rtol, atol=atol, err_msg=k)


def test_overlap_composes_with_expert_tp(mesh_dm22):
    """TP over ``data`` × grouped-EP over ``model`` × P=2 windows ≡ the
    serial grouped-TP path and the single-device reference."""
    x = jax.random.normal(RNG, (4, 16, D))
    p = _params(_cfg())
    y1, _, _ = _apply(mesh_dm22, _cfg(), p, x, tp="data")
    yp, _, _ = _apply(mesh_dm22, _cfg(2), p, x, tp="data")
    np.testing.assert_allclose(np.asarray(yp), np.asarray(y1),
                               rtol=1e-6, atol=1e-6)


def test_overlap_tp_ep_hier_full_mesh(mesh8):
    """The whole composition at once: (data=2, model=4) mesh, expert TP,
    hierarchical a2a (inner=2 × outer=2), P=2 — forward and grad match
    the serial path."""
    x = jax.random.normal(RNG, (8, 8, D))
    p = _params(_cfg())
    kw = dict(gate="switch", top_k=1, a2a="hierarchical", a2a_inner=2)

    def grad_fn(cfg):
        def loss(p, v):
            y, aux, _ = moe.sharded_moe_apply(
                mesh8, cfg, p, v, num_experts=E, act="swiglu",
                expert_tp_axis="data")
            return jnp.sum(y ** 2) + aux
        return jax.jit(jax.value_and_grad(loss))

    l1, g1 = grad_fn(_cfg(**kw))(p, x)
    lp, gp = grad_fn(_cfg(2, **kw))(p, x)
    np.testing.assert_allclose(float(lp), float(l1), rtol=1e-6)
    for k in p:
        np.testing.assert_allclose(np.asarray(gp[k]), np.asarray(g1[k]),
                                   rtol=1e-5, atol=1e-6, err_msg=k)


def test_overlap_pallas_matches_jnp(mesh_ep4):
    """The Pallas kernel path (fused gate, blocked gathers, grouped
    matmul fwd+bwd) drives the pipelined windows too."""
    x = jax.random.normal(RNG, (2, 16, D))
    res = {}
    for pall in (False, True):
        cfg = _cfg(2, gate="switch", top_k=1, capacity_factor=2.0,
                   use_pallas_gate=pall)
        p = _params(cfg)

        def loss(p, v, cfg=cfg):
            y, aux, _ = moe.sharded_moe_apply(mesh_ep4, cfg, p, v,
                                              num_experts=E, act="swiglu")
            return jnp.sum(y ** 2) + aux

        l, g = jax.jit(jax.value_and_grad(loss))(p, x)
        res[pall] = (float(l), float(jnp.linalg.norm(g["gate_w"])),
                     float(jnp.linalg.norm(g["w_up"])))
    np.testing.assert_allclose(res[False], res[True], rtol=1e-4)


def test_overlap_with_binding_bound_matches_serial_drops(mesh_ep4):
    """A binding segment bound drops the SAME rows chunked or not: the
    windows partition the already-clipped send counts, so the pipeline
    reproduces the serial path's outputs bit-for-bit."""
    cfg1 = _cfg(gate="switch", top_k=1, grouped_ep_bound_factor=0.5)
    cfgp = _cfg(2, gate="switch", top_k=1, grouped_ep_bound_factor=0.5)
    p = _params(cfg1)
    x = jax.random.normal(RNG, (8, 16, D))
    y1, _, _ = _apply(mesh_ep4, cfg1, p, x)
    yp, _, _ = _apply(mesh_ep4, cfgp, p, x)
    np.testing.assert_array_equal(np.asarray(yp), np.asarray(y1))


def test_overlap_token_padding_path(mesh_ep4):
    """Ragged decode batch (3 tokens on 4 devices): virtual-expert rows
    stay out of every window; output finite and equal to serial."""
    cfg = _cfg(gate="switch", top_k=1)
    p = _params(cfg)
    x = jax.random.normal(RNG, (3, 1, D))
    y1, _, _ = _apply(mesh_ep4, cfg, p, x)
    yp, _, _ = _apply(mesh_ep4, _cfg(2, gate="switch", top_k=1), p, x)
    assert bool(jnp.all(jnp.isfinite(yp)))
    np.testing.assert_allclose(np.asarray(yp), np.asarray(y1),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# jaxpr witness: the pipeline really emits P chunked all-to-alls
# (structured analysis.trace_graph walk — not a jaxpr-string grep)
# ---------------------------------------------------------------------------

def _trace(mesh, cfg, p, x):
    return analysis.trace_graph(
        lambda p_, v: moe.sharded_moe_apply(mesh, cfg, p_, v, num_experts=E,
                                            act="swiglu"),
        p, x,
        context={"cfg": cfg, "model_size": 4, "tokens_per_shard": 16,
                 "d_model": D, "direction": "fwd"})


@pytest.mark.parametrize("a2a,inner,per_chunk", [
    # flat: counts a2a + payload a2a + combine a2a per window
    ("flat", 1, 3),
    # hierarchical: counts + two-stage payload + two-stage combine
    ("hierarchical", 2, 5),
])
def test_overlap_emits_p_chunked_alltoalls(mesh_ep4, a2a, inner, per_chunk):
    p = _params(_cfg())
    x = jax.random.normal(RNG, (4, 16, D))    # T_local=16, K=2 → B=32
    for P in (1, 2, 4):
        cfg = _cfg(P, a2a=a2a, a2a_inner=inner)
        g = _trace(mesh_ep4, cfg, p, x)
        assert g.count("all_to_all") == per_chunk * P, (a2a, P)
        assert moe.expected_grouped_a2a_eqns(cfg, 4) == per_chunk * P
        # the overlap-chunk-count rule re-checks the count AND that the
        # payload exchanges move (M, B/P, d) windows, not the full bound
        assert analysis.run_rule("overlap-chunk-count", g) == [], (a2a, P)
        # none of the exchanges fell into a scan/while body
        assert analysis.run_rule("collective-in-loop", g) == [], (a2a, P)


def test_overlap_witness_has_teeth(mesh_ep4):
    """Lint the P=1 graph against a context claiming P=4: the rule must
    fire on both the equation count and the unsplit payload windows —
    i.e. the clean assertions above are not vacuous."""
    cfg1, cfg4 = _cfg(1), _cfg(4)
    p = _params(cfg1)
    x = jax.random.normal(RNG, (4, 16, D))
    g = _trace(mesh_ep4, cfg1, p, x)
    g.context["cfg"] = cfg4
    findings = analysis.run_rule("overlap-chunk-count", g)
    assert {f.rule for f in findings} == {"overlap-chunk-count"}
    assert len(findings) == 2, findings            # count + payload window
    assert all(f.level == "error" for f in findings)
