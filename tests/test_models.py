"""Sequence mixers: chunked-train ≡ step-recurrence; attention variants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import AttentionConfig, RWKVConfig, SSMConfig
from repro.models import attention as A
from repro.models import mamba2, rwkv6

RNG = jax.random.PRNGKey(4)


def test_rwkv6_chunked_equals_recurrent():
    cfg = RWKVConfig(head_dim=8, chunk_size=4, decay_lora=8, mix_lora=4)
    d, B, S = 16, 2, 16
    p = rwkv6.init_rwkv_block(RNG, cfg, d)
    x = jax.random.normal(RNG, (B, S, d), jnp.float32) * 0.5
    y_chunk, st_chunk = rwkv6.rwkv_time_mix(p, x, cfg)
    st = rwkv6.init_rwkv_state(cfg, B, d)
    ys = []
    for t in range(S):
        y, st = rwkv6.rwkv_decode_step(p, x[:, t:t + 1], st, cfg)
        ys.append(y)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_dec),
                               rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_chunk), np.asarray(st["s"]),
                               rtol=2e-3, atol=2e-4)


def test_mamba2_chunked_equals_recurrent():
    cfg = SSMConfig(d_state=8, head_dim=8, expand=2, chunk_size=4,
                    conv_width=4, n_groups=1)
    d, B, S = 16, 2, 16
    p = mamba2.init_mamba_block(RNG, cfg, d)
    x = jax.random.normal(RNG, (B, S, d), jnp.float32) * 0.5
    y_chunk, st_chunk = mamba2.mamba_forward(p, x, cfg, d)
    st = mamba2.init_mamba_state(cfg, B, d)
    ys = []
    for t in range(S):
        y, st = mamba2.mamba_decode_step(p, x[:, t:t + 1], st, cfg, d)
        ys.append(y)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_dec),
                               rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_chunk["s"]), np.asarray(st["s"]),
                               rtol=2e-3, atol=2e-4)


def _mk_attn(kv=2, window=None, cap=None, rope=True):
    return AttentionConfig(num_heads=4, num_kv_heads=kv, head_dim=8,
                           window=window, attn_softcap=cap, use_rope=rope)


def test_attention_decode_matches_full():
    """Teacher-forced decode reproduces the full causal pass."""
    cfg = _mk_attn()
    d, B, S = 32, 2, 12
    p = A.init_attention(RNG, cfg, d)
    x = jax.random.normal(RNG, (B, S, d), jnp.float32)
    y_full, _ = A.full_attention(p, x, cfg, positions=jnp.arange(S))
    cache = A.init_cache(cfg, B, S, d, jnp.float32)
    ys = []
    for t in range(S):
        y, cache = A.decode_attention(p, x[:, t:t + 1], cache, cfg)
        ys.append(y)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_dec),
                               rtol=1e-4, atol=1e-5)


def test_ring_cache_matches_full_swa():
    """Ring decode with W-bounded cache ≡ full sliding-window attention."""
    W = 4
    cfg = _mk_attn(window=W)
    d, B, S = 32, 2, 16
    p = A.init_attention(RNG, cfg, d)
    x = jax.random.normal(RNG, (B, S, d), jnp.float32)
    y_full, _ = A.full_attention(p, x, cfg, positions=jnp.arange(S))
    cache = A.init_cache(cfg, B, W, d, jnp.float32)   # bounded!
    ys = []
    for t in range(S):
        y, cache = A.decode_attention(p, x[:, t:t + 1], cache, cfg, ring=True)
        ys.append(y)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_dec),
                               rtol=1e-4, atol=1e-5)


def test_ring_prefill_then_decode_continues():
    """Over-long prefill into a ring cache, then decode — matches full."""
    W = 4
    cfg = _mk_attn(window=W)
    d, B, S = 32, 1, 11
    p = A.init_attention(RNG, cfg, d)
    x = jax.random.normal(RNG, (B, S + 1, d), jnp.float32)
    y_full, _ = A.full_attention(p, x, cfg, positions=jnp.arange(S + 1))
    _, kv = A.full_attention(p, x[:, :S], cfg, positions=jnp.arange(S))
    cache = A.fill_cache(A.init_cache(cfg, B, W, d, jnp.float32), kv, ring=True)
    y, _ = A.decode_attention(p, x[:, S:S + 1], cache, cfg, ring=True)
    np.testing.assert_allclose(np.asarray(y[:, 0]), np.asarray(y_full[:, S]),
                               rtol=1e-4, atol=1e-5)


def test_q_chunked_equals_unchunked():
    cfg = _mk_attn()
    d, B, S = 32, 2, 16
    p = A.init_attention(RNG, cfg, d)
    x = jax.random.normal(RNG, (B, S, d), jnp.float32)
    y1, _ = A.full_attention(p, x, cfg, positions=jnp.arange(S), q_chunk=4)
    y2, _ = A.full_attention(p, x, cfg, positions=jnp.arange(S), q_chunk=64)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-6)


def test_softcap_bounds_scores():
    cfg = _mk_attn(cap=5.0)
    d, B, S = 32, 1, 8
    p = A.init_attention(RNG, cfg, d)
    x = jax.random.normal(RNG, (B, S, d), jnp.float32) * 10
    y, _ = A.full_attention(p, x, cfg, positions=jnp.arange(S))
    assert bool(jnp.all(jnp.isfinite(y)))


def test_encoder_mode_is_bidirectional():
    cfg = AttentionConfig(num_heads=4, num_kv_heads=4, head_dim=8,
                          use_rope=False, causal=False)
    d, B, S = 32, 1, 8
    p = A.init_attention(RNG, cfg, d)
    x = jax.random.normal(RNG, (B, S, d), jnp.float32)
    y, _ = A.full_attention(p, x, cfg, positions=jnp.arange(S), causal=False)
    # position 0's output depends on position S-1's input (bidirectional)
    x2 = x.at[:, -1].add(1.0)
    y2, _ = A.full_attention(p, x2, cfg, positions=jnp.arange(S), causal=False)
    assert float(jnp.max(jnp.abs(y2[:, 0] - y[:, 0]))) > 1e-6
