"""Full MoE layer (paper Alg. 1): expert-parallel exactness, a2a modes,
dispatch modes, padding, gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import moe
from repro.core.config import MoEConfig

RNG = jax.random.PRNGKey(3)
D = 32


def _params(cfg, dtype=jnp.float32):
    return moe.init_moe_params(RNG, cfg, D, 64, cfg.num_experts,
                               act="swiglu", dtype=dtype)


def _apply(mesh, cfg, params, x):
    return jax.jit(lambda p, v: moe.sharded_moe_apply(
        mesh, cfg, p, v, num_experts=cfg.num_experts, act="swiglu"))(params, x)


def test_ep_exact_vs_single_device(mesh1, mesh8):
    """Deterministic gate + ample capacity: 8-way EP is bit-exact."""
    cfg = MoEConfig(num_experts=8, gate="topk", top_k=2, capacity_factor=8.0)
    p = _params(cfg)
    x = jax.random.normal(RNG, (4, 16, D))
    y1, _, _ = _apply(mesh1, cfg, p, x)
    y8, _, _ = _apply(mesh8, cfg, p, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y8),
                               rtol=1e-4, atol=1e-5)


def test_hierarchical_a2a_equals_flat_in_layer(mesh8):
    cfg = MoEConfig(num_experts=8, gate="switch", capacity_factor=4.0)
    cfgh = MoEConfig(num_experts=8, gate="switch", capacity_factor=4.0,
                     a2a="hierarchical", a2a_inner=2)
    p = _params(cfg)
    x = jax.random.normal(RNG, (4, 16, D))
    yf, _, _ = _apply(mesh8, cfg, p, x)
    yh, _, _ = _apply(mesh8, cfgh, p, x)
    np.testing.assert_allclose(np.asarray(yf), np.asarray(yh),
                               rtol=1e-5, atol=1e-6)


def test_dense_dispatch_equals_sort_dispatch(mesh8):
    cfgs = MoEConfig(num_experts=8, gate="gshard", capacity_factor=4.0,
                     dispatch="sort")
    cfgd = MoEConfig(num_experts=8, gate="gshard", capacity_factor=4.0,
                     dispatch="dense")
    p = _params(cfgs)
    x = jax.random.normal(RNG, (4, 16, D))
    ys, _, _ = _apply(mesh8, cfgs, p, x)
    yd, _, _ = _apply(mesh8, cfgd, p, x)
    np.testing.assert_allclose(np.asarray(ys), np.asarray(yd),
                               rtol=1e-4, atol=1e-5)


def test_token_padding_path(mesh8):
    """Token counts that don't divide the device count (decode batches)."""
    cfg = MoEConfig(num_experts=8, gate="switch", capacity_factor=4.0)
    p = _params(cfg)
    x = jax.random.normal(RNG, (3, 1, D))        # 3 tokens, 8 devices
    y, aux, m = _apply(mesh8, cfg, p, x)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))


@pytest.mark.parametrize("gate,kw", [
    ("switch", {}), ("gshard", {}), ("topk", dict(top_k=2)),
    ("ktop1", dict(num_prototypes=2)), ("sam", dict(num_groups=2, top_k=2)),
    ("base", {}), ("dense_to_sparse", dict(top_k=2))])
def test_all_gates_through_layer(mesh8, gate, kw):
    cfg = MoEConfig(num_experts=8, gate=gate, capacity_factor=4.0, **kw)
    p = _params(cfg)
    x = jax.random.normal(RNG, (4, 8, D))
    y, aux, metrics = _apply(mesh8, cfg, p, x)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(aux))
    assert bool(jnp.all(jnp.isfinite(y)))


def test_gradients_flow_multidevice(mesh8):
    cfg = MoEConfig(num_experts=8, gate="switch", capacity_factor=4.0)
    p = _params(cfg)
    x = jax.random.normal(RNG, (4, 16, D))

    def loss(p, v):
        y, aux, _ = moe.sharded_moe_apply(mesh8, cfg, p, v,
                                          num_experts=8, act="swiglu")
        return jnp.sum(y ** 2) + aux

    g = jax.jit(jax.grad(loss))(p, x)
    for k, v in g.items():
        assert bool(jnp.all(jnp.isfinite(v))), k
        assert float(jnp.linalg.norm(v)) > 0, k


def test_pallas_path_matches_jnp_path(mesh1):
    res = {}
    for pall in (False, True):
        cfg = MoEConfig(num_experts=8, gate="switch", capacity_factor=2.0,
                        use_pallas_gate=pall)
        p = _params(cfg)
        x = jax.random.normal(RNG, (2, 16, D))

        def loss(p, v):
            y, aux, _ = moe.sharded_moe_apply(mesh1, cfg, p, v,
                                              num_experts=8, act="swiglu")
            return jnp.sum(y ** 2) + aux

        l, g = jax.jit(jax.value_and_grad(loss))(p, x)
        res[pall] = (float(l), float(jnp.linalg.norm(g["gate_w"])),
                     float(jnp.linalg.norm(g["w_up"])))
    np.testing.assert_allclose(res[False], res[True], rtol=1e-4)


def test_capacity_drop_rate_metrics(mesh1):
    """With cf=0.25 roughly 3/4 of tokens drop; layer output stays finite."""
    cfg = MoEConfig(num_experts=4, gate="switch", capacity_factor=0.25)
    p = _params(cfg)
    x = jax.random.normal(RNG, (8, 32, D))
    y, aux, m = _apply(mesh1, cfg, p, x)
    assert bool(jnp.all(jnp.isfinite(y)))
    # heavy imbalance shows up in the load metric
    assert float(m["expert_load_max"]) >= 0.25


def test_expert_tp_equals_gathered(mesh8):
    """§Perf decode mode: expert-TP over data ≡ the gathered baseline."""
    cfg = MoEConfig(num_experts=4, gate="switch", capacity_factor=4.0)
    p = _params(cfg)
    x = jax.random.normal(RNG, (8, 4, D))
    y0, _, _ = jax.jit(lambda p, v: moe.sharded_moe_apply(
        mesh8, cfg, p, v, num_experts=4, act="swiglu"))(p, x)
    y1, _, _ = jax.jit(lambda p, v: moe.sharded_moe_apply(
        mesh8, cfg, p, v, num_experts=4, act="swiglu",
        expert_tp_axis="data"))(p, x)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=1e-4, atol=1e-5)


def test_expert_tp_typo_raises(mesh8):
    """A typo'd expert_tp_axis must fail loudly, not silently disable TP."""
    cfg = MoEConfig(num_experts=4, gate="switch", capacity_factor=4.0)
    p = _params(cfg)
    x = jax.random.normal(RNG, (8, 4, D))
    with pytest.raises(ValueError, match="expert_tp_axis"):
        moe.sharded_moe_apply(mesh8, cfg, p, x, num_experts=4, act="swiglu",
                              expert_tp_axis="dataa")
