"""Full MoE layer (paper Alg. 1): expert-parallel exactness, a2a modes,
dispatch modes, padding, gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import moe
from repro.core.config import MoEConfig

RNG = jax.random.PRNGKey(3)
D = 32


def _params(cfg, dtype=jnp.float32):
    return moe.init_moe_params(RNG, cfg, D, 64, cfg.num_experts,
                               act="swiglu", dtype=dtype)


def _apply(mesh, cfg, params, x):
    return jax.jit(lambda p, v: moe.sharded_moe_apply(
        mesh, cfg, p, v, num_experts=cfg.num_experts, act="swiglu"))(params, x)


def test_ep_exact_vs_single_device(mesh1, mesh8):
    """Deterministic gate + ample capacity: 8-way EP is bit-exact."""
    cfg = MoEConfig(num_experts=8, gate="topk", top_k=2, capacity_factor=8.0)
    p = _params(cfg)
    x = jax.random.normal(RNG, (4, 16, D))
    y1, _, _ = _apply(mesh1, cfg, p, x)
    y8, _, _ = _apply(mesh8, cfg, p, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y8),
                               rtol=1e-4, atol=1e-5)


def test_hierarchical_a2a_equals_flat_in_layer(mesh8):
    cfg = MoEConfig(num_experts=8, gate="switch", capacity_factor=4.0)
    cfgh = MoEConfig(num_experts=8, gate="switch", capacity_factor=4.0,
                     a2a="hierarchical", a2a_inner=2)
    p = _params(cfg)
    x = jax.random.normal(RNG, (4, 16, D))
    yf, _, _ = _apply(mesh8, cfg, p, x)
    yh, _, _ = _apply(mesh8, cfgh, p, x)
    np.testing.assert_allclose(np.asarray(yf), np.asarray(yh),
                               rtol=1e-5, atol=1e-6)


def test_dense_dispatch_equals_sort_dispatch(mesh8):
    cfgs = MoEConfig(num_experts=8, gate="gshard", capacity_factor=4.0,
                     dispatch="sort")
    cfgd = MoEConfig(num_experts=8, gate="gshard", capacity_factor=4.0,
                     dispatch="dense")
    p = _params(cfgs)
    x = jax.random.normal(RNG, (4, 16, D))
    ys, _, _ = _apply(mesh8, cfgs, p, x)
    yd, _, _ = _apply(mesh8, cfgd, p, x)
    np.testing.assert_allclose(np.asarray(ys), np.asarray(yd),
                               rtol=1e-4, atol=1e-5)


def test_token_padding_path(mesh8):
    """Token counts that don't divide the device count (decode batches)."""
    cfg = MoEConfig(num_experts=8, gate="switch", capacity_factor=4.0)
    p = _params(cfg)
    x = jax.random.normal(RNG, (3, 1, D))        # 3 tokens, 8 devices
    y, aux, m = _apply(mesh8, cfg, p, x)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))


@pytest.mark.parametrize("gate,kw", [
    ("switch", {}), ("gshard", {}), ("topk", dict(top_k=2)),
    ("ktop1", dict(num_prototypes=2)), ("sam", dict(num_groups=2, top_k=2)),
    # sam with top_k > E/G: gate_k clamps, capacity sizes off the clamp
    ("sam", dict(num_groups=4, top_k=8)),
    ("base", {}), ("dense_to_sparse", dict(top_k=2))])
def test_all_gates_through_layer(mesh8, gate, kw):
    cfg = MoEConfig(num_experts=8, gate=gate, capacity_factor=4.0, **kw)
    p = _params(cfg)
    x = jax.random.normal(RNG, (4, 8, D))
    y, aux, metrics = _apply(mesh8, cfg, p, x)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(aux))
    assert bool(jnp.all(jnp.isfinite(y)))


def test_gradients_flow_multidevice(mesh8):
    cfg = MoEConfig(num_experts=8, gate="switch", capacity_factor=4.0)
    p = _params(cfg)
    x = jax.random.normal(RNG, (4, 16, D))

    def loss(p, v):
        y, aux, _ = moe.sharded_moe_apply(mesh8, cfg, p, v,
                                          num_experts=8, act="swiglu")
        return jnp.sum(y ** 2) + aux

    g = jax.jit(jax.grad(loss))(p, x)
    for k, v in g.items():
        assert bool(jnp.all(jnp.isfinite(v))), k
        assert float(jnp.linalg.norm(v)) > 0, k


def test_pallas_path_matches_jnp_path(mesh1):
    res = {}
    for pall in (False, True):
        cfg = MoEConfig(num_experts=8, gate="switch", capacity_factor=2.0,
                        use_pallas_gate=pall)
        p = _params(cfg)
        x = jax.random.normal(RNG, (2, 16, D))

        def loss(p, v):
            y, aux, _ = moe.sharded_moe_apply(mesh1, cfg, p, v,
                                              num_experts=8, act="swiglu")
            return jnp.sum(y ** 2) + aux

        l, g = jax.jit(jax.value_and_grad(loss))(p, x)
        res[pall] = (float(l), float(jnp.linalg.norm(g["gate_w"])),
                     float(jnp.linalg.norm(g["w_up"])))
    np.testing.assert_allclose(res[False], res[True], rtol=1e-4)


def test_capacity_drop_rate_metrics(mesh1):
    """With cf=0.25 roughly 3/4 of tokens drop; layer output stays finite."""
    cfg = MoEConfig(num_experts=4, gate="switch", capacity_factor=0.25)
    p = _params(cfg)
    x = jax.random.normal(RNG, (8, 32, D))
    y, aux, m = _apply(mesh1, cfg, p, x)
    assert bool(jnp.all(jnp.isfinite(y)))
    # heavy imbalance shows up in the load metric
    assert float(m["expert_load_max"]) >= 0.25


def test_expert_tp_equals_gathered(mesh8):
    """§Perf decode mode: expert-TP over data ≡ the gathered baseline."""
    cfg = MoEConfig(num_experts=4, gate="switch", capacity_factor=4.0)
    p = _params(cfg)
    x = jax.random.normal(RNG, (8, 4, D))
    y0, _, _ = jax.jit(lambda p, v: moe.sharded_moe_apply(
        mesh8, cfg, p, v, num_experts=4, act="swiglu"))(p, x)
    y1, _, _ = jax.jit(lambda p, v: moe.sharded_moe_apply(
        mesh8, cfg, p, v, num_experts=4, act="swiglu",
        expert_tp_axis="data"))(p, x)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=1e-4, atol=1e-5)


def test_hash_gate_requires_token_ids(mesh8):
    """Without token_ids the wrapper used to substitute zeros — every
    token hashed to one bucket and the hash gate silently degenerated to
    a single expert.  Now it must fail loudly."""
    cfg = MoEConfig(num_experts=8, gate="hash")
    p = _params(cfg)
    x = jax.random.normal(RNG, (4, 8, D))
    with pytest.raises(ValueError, match="token_ids"):
        moe.sharded_moe_apply(mesh8, cfg, p, x, num_experts=8, act="swiglu")
    # with real ids the layer runs and spreads load over several buckets
    tid = jnp.arange(32).reshape(4, 8)
    y, aux, m = jax.jit(lambda p, v, t: moe.sharded_moe_apply(
        mesh8, cfg, p, v, num_experts=8, act="swiglu", token_ids=t))(p, x, tid)
    assert y.shape == x.shape
    assert float(m["expert_load_max"]) < 1.0


@pytest.mark.parametrize("dispatch", ["sort", "grouped"])
def test_aux_losses_ignore_padded_tokens(dispatch):
    """A padded batch (decode-style T % n_dev != 0) must report the SAME
    aux losses and router metrics as its unpadded twin: the virtual-expert
    rows used to inflate the z-loss (logsumexp(0)² = log(E)² each) and
    deflate the load-balance means."""
    cfg = MoEConfig(num_experts=8, gate="switch", capacity_factor=8.0,
                    dispatch=dispatch, router_z_loss_weight=1e-3)
    p = _params(cfg)
    T, pad = 56, 8
    x = jax.random.normal(RNG, (T, D))
    xp = jnp.concatenate([x, jnp.zeros((pad, D))])
    valid = jnp.arange(T + pad) < T
    y, aux, m = moe.moe_block_local(cfg, p, x, num_experts=8, act="swiglu")
    yp, auxp, mp = moe.moe_block_local(cfg, p, xp, num_experts=8,
                                       act="swiglu", valid=valid)
    np.testing.assert_allclose(float(auxp), float(aux), rtol=1e-6)
    for k in m:
        np.testing.assert_allclose(float(mp[k]), float(m[k]),
                                   rtol=1e-6, err_msg=k)
    np.testing.assert_allclose(np.asarray(yp[:T]), np.asarray(y),
                               rtol=1e-5, atol=1e-6)


def test_aux_losses_ignore_padding_under_sharding(mesh1, mesh8):
    """T=57 on 8 devices pads 7 rows onto the LAST shard: per-shard
    masked means pmean'd would weight that shard's 1 valid token like a
    full shard of 8.  The (sum, count) psum aggregation makes the
    sharded lb/z-loss exactly the unsharded 57-token values."""
    cfg = MoEConfig(num_experts=8, gate="switch", capacity_factor=8.0,
                    router_z_loss_weight=1e-3)
    p = _params(cfg)
    x = jax.random.normal(RNG, (57, D))
    _, aux1, m1 = _apply(mesh1, cfg, p, x)
    _, aux8, m8 = _apply(mesh8, cfg, p, x)
    np.testing.assert_allclose(float(aux8), float(aux1), rtol=1e-5)
    for k in ("load_balance_loss", "router_z_loss"):
        np.testing.assert_allclose(float(m8[k]), float(m1[k]),
                                   rtol=1e-5, err_msg=k)


def test_expert_tp_typo_raises(mesh8):
    """A typo'd expert_tp_axis must fail loudly, not silently disable TP."""
    cfg = MoEConfig(num_experts=4, gate="switch", capacity_factor=4.0)
    p = _params(cfg)
    x = jax.random.normal(RNG, (8, 4, D))
    with pytest.raises(ValueError, match="expert_tp_axis"):
        moe.sharded_moe_apply(mesh8, cfg, p, x, num_experts=4, act="swiglu",
                              expert_tp_axis="dataa")


def test_config_mode_typos_raise_under_optimization():
    """gate/a2a/dispatch typos must raise real ValueErrors naming the
    valid options — the old bare asserts vanish under ``python -O``."""
    with pytest.raises(ValueError, match="topp.*topk|gating strategy"):
        MoEConfig(num_experts=8, gate="topp")
    with pytest.raises(ValueError, match="'flat', 'hierarchical'"):
        MoEConfig(num_experts=8, a2a="ring")
    with pytest.raises(ValueError, match="'sort', 'dense', 'grouped'"):
        MoEConfig(num_experts=8, dispatch="padded")


def test_metrics_out_specs_track_balance_keys(mesh8):
    """The shard_map metric out_specs derive from balance.METRIC_KEYS —
    the layer's returned metrics dict must carry exactly those keys."""
    from repro.core import balance
    cfg = MoEConfig(num_experts=8, gate="switch", capacity_factor=4.0)
    p = _params(cfg)
    x = jax.random.normal(RNG, (4, 8, D))
    _, _, m = _apply(mesh8, cfg, p, x)
    assert tuple(sorted(m)) == tuple(sorted(balance.METRIC_KEYS))
