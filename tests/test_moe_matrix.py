"""Property-based routing-equivalence matrix.

One generative suite replaces the hand-picked corners: random draws over
(gate strategy × dispatch × a2a mode × dtype × ragged token counts)
assert that ``sharded_moe_apply`` matches the dense per-token reference
and that sort ≡ grouped ≡ grouped+overlap within per-dtype tolerances.

Two layers of generation:

* the always-run seeded matrix — one deterministic draw per gate
  strategy (``np.random.RandomState``-seeded, so failures reproduce),
  alternating the single-device and the 4-way expert-parallel mesh;
* the hypothesis sweep (slow-marked, hypothesis-optional via
  ``hypothesis_compat`` — skips cleanly when the package is absent)
  which searches the same space freely.

Equivalence only holds where every mode computes every token: capacity
factor is ample (the padded modes drop nothing) and the grouped bound is
dropless (default).  Stochastic gates (gshard's sampled 2nd expert,
dense_to_sparse's gumbel noise) stay equivalent because all modes share
one rng fold per shard.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import hypothesis, st
from repro.core import capacity, gating, moe
from repro.core.config import GATE_STRATEGIES, MoEConfig

D = 16
TOL = {"float32": dict(rtol=1e-4, atol=1e-5),
       "bfloat16": dict(rtol=2e-2, atol=2e-2)}

# Quantized-exchange cells (payload_dtype set): the dispatch AND combine
# payloads each take one trip through the low-precision wire, so the
# output error is bounded by two per-chunk grid steps amplified by the
# FFN's Lipschitz factor.  Measured on this matrix (f32 compute):
# int8 lands near 1–2% relative, float8_e4m3fn (3 mantissa bits) near
# 3–5%; the tolerances below leave ~3× headroom over those medians.
QTOL = {"int8": dict(rtol=5e-2, atol=5e-2),
        "float8_e4m3fn": dict(rtol=1.5e-1, atol=1.5e-1)}


def _gate_kwargs(rs, gate, E):
    kw = {}
    if gate == "topk":
        kw["top_k"] = int(rs.choice([2, 3]))
    elif gate == "ktop1":
        kw["num_prototypes"] = int(rs.choice([2, 4]))
    elif gate == "sam":
        kw["num_groups"] = int(rs.choice([2, 4]))
        kw["top_k"] = 2
    elif gate == "dense_to_sparse":
        kw["top_k"] = 2
    return kw


def _dense_reference(cfg, params, x, rng, tid, act="swiglu"):
    """Per-token weighted expert-FFN sum — no dispatch machinery at all.
    Mirrors the layer's single-shard rng fold (axis index 0)."""
    S, _ = x.shape
    E = cfg.num_experts
    logits = gating.router_logits(cfg, x, params["gate_w"])
    g = gating.route(cfg, logits, rng=jax.random.fold_in(rng, 0),
                     token_ids=tid)
    pe = {k: v for k, v in params.items() if k != "gate_w"}
    ye = moe.expert_ffn(pe, jnp.broadcast_to(
        x, (E, S, x.shape[-1])).astype(pe["w_up"].dtype), act)  # (E, S, d)
    out = jnp.zeros((S, x.shape[-1]), jnp.float32)
    for k in range(g.expert_index.shape[-1]):
        rows = ye[g.expert_index[:, k], jnp.arange(S)].astype(jnp.float32)
        out = out + g.combine_weights[:, k:k + 1] * rows
    return out


def _run_case(mesh, gate, E, kw, S, dtype, a2a, seed, payload_dtype=None):
    """One matrix draw: dense / sort / grouped / grouped+overlap on the
    given mesh, all against the dispatch='dense' output (and, on the
    single-device mesh, against the explicit per-token reference).
    With ``payload_dtype`` set, quantized grouped and grouped+overlap
    cells join the draw: within ``QTOL`` of dense on EP meshes, and
    BITWISE equal to the unquantized grouped cell when model_size == 1
    (the documented no-op — no exchange, nothing to quantize)."""
    base = dict(num_experts=E, gate=gate, capacity_factor=8.0,
                a2a=a2a, a2a_inner=2, **kw)
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (S, D)).astype(dtype)
    cfg0 = MoEConfig(**base)
    params = moe.init_moe_params(key, cfg0, D, 32, E, act="swiglu",
                                 dtype=jnp.dtype(dtype))
    tid = (jnp.arange(S, dtype=jnp.int32) * 7 + seed) % 1013
    rng = jax.random.PRNGKey(seed + 1)

    # the largest chunk count that divides this draw's segment bound
    # (ragged S on the single-device mesh can make T·K odd)
    n_dev = mesh.devices.size
    T_local = (S + (-S) % n_dev) // n_dev
    M = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    B = (capacity.grouped_segment_bound(cfg0, T_local, M) if M > 1
         else capacity.grouped_tp_gather_bound(cfg0, T_local))
    P = next(p for p in (4, 2, 1) if B % p == 0)

    modes = [("dense", {"dispatch": "dense"}),
             ("sort", {"dispatch": "sort"}),
             ("grouped", {"dispatch": "grouped"}),
             ("overlap", {"dispatch": "grouped", "overlap_chunks": P})]
    if payload_dtype is not None:
        modes += [("qgrouped", {"dispatch": "grouped",
                                "payload_dtype": payload_dtype}),
                  ("qoverlap", {"dispatch": "grouped", "overlap_chunks": P,
                                "payload_dtype": payload_dtype})]
    ys, auxes = {}, {}
    for name, over in modes:
        cfg = MoEConfig(**{**base, **over})
        y, aux, _ = jax.jit(lambda p, v, cfg=cfg: moe.sharded_moe_apply(
            mesh, cfg, p, v, num_experts=E, act="swiglu", rng=rng,
            token_ids=tid))(params, x)
        ys[name] = np.asarray(y, np.float32)
        auxes[name] = float(aux)

    tol = TOL[jnp.dtype(dtype).name]
    for name in ("sort", "grouped", "overlap"):
        np.testing.assert_allclose(
            ys[name], ys["dense"], err_msg=f"{gate}/{name} vs dense", **tol)
        np.testing.assert_allclose(auxes[name], auxes["dense"], rtol=1e-5,
                                   err_msg=f"{gate}/{name} aux")
    if payload_dtype is not None:
        qtol = {k: max(v, TOL[jnp.dtype(dtype).name][k])
                for k, v in QTOL[payload_dtype].items()}
        for name in ("qgrouped", "qoverlap"):
            if M > 1:
                np.testing.assert_allclose(
                    ys[name], ys["dense"],
                    err_msg=f"{gate}/{name}[{payload_dtype}] vs dense",
                    **qtol)
            else:
                # model_size == 1: payload_dtype is a documented no-op
                np.testing.assert_array_equal(
                    ys[name], ys[name.lstrip("q")],
                    err_msg=f"{gate}/{name}[{payload_dtype}] must be a "
                            f"no-op on the 1-rank mesh")
            np.testing.assert_allclose(
                auxes[name], auxes["dense"], rtol=1e-5,
                err_msg=f"{gate}/{name}[{payload_dtype}] aux")
    if n_dev == 1:
        ref = np.asarray(_dense_reference(cfg0, params, x, rng, tid),
                         np.float32)
        np.testing.assert_allclose(ys["dense"], ref,
                                   err_msg=f"{gate} vs reference", **tol)
    return P


# ---------------------------------------------------------------------------
# always-run seeded matrix: one draw per gate strategy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("i,gate", list(enumerate(GATE_STRATEGIES)))
def test_routing_equivalence_matrix(i, gate, mesh1, mesh_ep4):
    rs = np.random.RandomState(4000 + i)
    E = int(rs.choice([8, 16]))
    kw = _gate_kwargs(rs, gate, E)
    S = int(rs.randint(5, 48))               # ragged → exercises padding
    dtype = ["float32", "bfloat16"][int(rs.randint(2))]
    a2a = ["flat", "hierarchical"][int(rs.randint(2))]
    mesh = mesh1 if i % 2 == 0 else mesh_ep4
    _run_case(mesh, gate, E, kw, S, dtype, a2a, seed=300 + i)


# ---------------------------------------------------------------------------
# decode-shaped draws: S=1 and tiny ragged batches (the serving step).
# The serving path now runs dispatch="grouped" for decode, so routing
# equivalence must hold at exactly these shapes — a single token per
# step and small ragged batches far below the expert count.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S", [1, 2, 3, 5])
@pytest.mark.parametrize("mesh_name", ["mesh1", "mesh_ep4"])
def test_routing_equivalence_decode_shapes(S, mesh_name, request):
    mesh = request.getfixturevalue(mesh_name)
    rs = np.random.RandomState(7000 + S)
    gate = GATE_STRATEGIES[int(rs.randint(len(GATE_STRATEGIES)))]
    E = int(rs.choice([8, 16]))
    _run_case(mesh, gate, E, _gate_kwargs(rs, gate, E), S, "float32",
              ["flat", "hierarchical"][int(rs.randint(2))], seed=900 + S)


# ---------------------------------------------------------------------------
# hypothesis sweep (slow; skips when hypothesis is not installed)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@hypothesis.settings(max_examples=12, deadline=None)
@hypothesis.given(data=st.data())
def test_routing_equivalence_hypothesis(data, mesh_ep4):
    gate = data.draw(st.sampled_from(GATE_STRATEGIES))
    rs = np.random.RandomState(data.draw(st.integers(0, 2 ** 16)))
    E = data.draw(st.sampled_from([8, 16]))
    kw = _gate_kwargs(rs, gate, E)
    S = data.draw(st.integers(min_value=3, max_value=64))
    dtype = data.draw(st.sampled_from(["float32", "bfloat16"]))
    a2a = data.draw(st.sampled_from(["flat", "hierarchical"]))
    seed = data.draw(st.integers(0, 2 ** 16))
    _run_case(mesh_ep4, gate, E, kw, S, dtype, a2a, seed)


# ---------------------------------------------------------------------------
# quantized payload cells (PR 10): int8 / fp8 exchange wire, f32 compute.
# mesh1 pins the documented no-op (bitwise equal to unquantized grouped);
# mesh_ep4 exercises the EP exchange; mesh_dm22 adds a data axis so the
# token sharding and the 2-way model exchange compose.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("i,qdt,mesh_name", [
    (0, "int8", "mesh1"),
    (1, "int8", "mesh_ep4"),
    (2, "int8", "mesh_dm22"),
    (3, "float8_e4m3fn", "mesh_ep4"),
    (4, "float8_e4m3fn", "mesh_dm22"),
])
def test_routing_equivalence_quantized_payload(i, qdt, mesh_name, request):
    mesh = request.getfixturevalue(mesh_name)
    rs = np.random.RandomState(5100 + i)
    gate = GATE_STRATEGIES[int(rs.randint(len(GATE_STRATEGIES)))]
    E = int(rs.choice([8, 16]))
    kw = _gate_kwargs(rs, gate, E)
    S = int(rs.randint(5, 48))
    a2a = ["flat", "hierarchical"][int(rs.randint(2))]
    _run_case(mesh, gate, E, kw, S, "float32", a2a, seed=1300 + i,
              payload_dtype=qdt)
