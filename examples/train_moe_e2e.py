"""End-to-end driver: train an MoE language model on synthetic data.

Defaults are CPU-sized (~7M params, 200 steps, loss visibly falls).
``--hundred-m`` switches to a ~100M-param 16-expert model — the
configuration this driver runs for a few hundred steps on one real v5e
host (it is only *slow*, not different, on CPU).

  PYTHONPATH=src python examples/train_moe_e2e.py [--steps 200] [--hundred-m]
"""
import argparse
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax

from repro.core.config import (AttentionConfig, ModelConfig, MoEConfig,
                               TrainConfig)
from repro.data import SyntheticLM
from repro.launch.mesh import make_smoke_mesh
from repro.launch.train import run as train_run
from repro import configs


def small_moe(hundred_m: bool) -> ModelConfig:
    if hundred_m:
        d, f, L, E, V = 512, 1024, 8, 16, 32000      # ≈100M params
    else:
        d, f, L, E, V = 128, 256, 4, 8, 2048         # ≈7M params (CPU)
    return ModelConfig(
        name="moe-e2e", family="moe", num_layers=L, d_model=d, d_ff=f,
        vocab_size=V, block_pattern=("dense", "moe"),
        attention=AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=32),
        moe=MoEConfig(num_experts=E, top_k=1, gate="switch",
                      capacity_factor=1.5, dispatch="sort"),
        act="swiglu")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--hundred-m", action="store_true")
    args = ap.parse_args()
    cfg = small_moe(args.hundred_m)
    configs.ARCHS[cfg.name] = cfg          # register for the train driver
    state, history = train_run(cfg.name, steps=args.steps, batch=args.batch,
                               seq=args.seq, smoke=False, lr=3e-3,
                               mesh_shape=(1, 1), log_every=20)
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"\nloss {first:.3f} -> {last:.3f} "
          f"({'FELL ✓' if last < first - 0.3 else 'did not fall ✗'})")


if __name__ == "__main__":
    main()
