"""Tour of all 8 gating strategies (paper Fig. 2 — the usability axis).

Routes the same tokens through every strategy and prints the per-expert
load profile + drop rate under a fixed capacity — making the balance
trade-offs (greedy switch vs structurally-balanced BASE vs hash, etc.)
visible side by side.

  PYTHONPATH=src python examples/gating_tour.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import capacity, gating, layout
from repro.core.config import MoEConfig

STRATEGIES = [
    ("switch", dict()),
    ("gshard", dict()),
    ("topk", dict(top_k=2)),
    ("ktop1", dict(num_prototypes=2)),
    ("sam", dict(num_groups=4, top_k=2)),
    ("base", dict()),
    ("hash", dict()),
    ("dense_to_sparse", dict(top_k=2, gumbel_temperature=0.5)),
]


def main():
    S, E = 512, 8
    rng = jax.random.PRNGKey(0)
    # mildly skewed router inputs — the realistic hard case for balance
    logits = jax.random.normal(rng, (S, E)) + \
        jnp.linspace(1.0, 0.0, E)[None, :]
    token_ids = jax.random.randint(rng, (S,), 0, 50000)

    print(f"{'strategy':18s} {'k':>2s} {'load per expert (of {:d} tokens)'.format(S):40s} "
          f"{'drop%':>6s}")
    for name, kw in STRATEGIES:
        cfg = MoEConfig(num_experts=E, gate=name, capacity_factor=1.25, **kw)
        out = gating.route(cfg, logits, rng=rng, token_ids=token_ids)
        k = gating.gate_k(cfg)
        C = capacity.expert_capacity(cfg, S, E)
        plan = layout.plan_sort(out, E, C)
        counts = np.bincount(np.asarray(out.expert_index).ravel(), minlength=E)
        dropped = float(np.mean(np.asarray(plan.slot) < 0)) * 100
        print(f"{name:18s} {k:2d} {str(counts.tolist()):40s} {dropped:5.1f}%")


if __name__ == "__main__":
    main()
