"""Batched serving: prefill a batch of prompts, then decode step-by-step
with a shared batched KV cache — the ``serve_step`` the decode dry-run
shapes lower, driven end-to-end.

  PYTHONPATH=src python examples/serve_batched.py [--arch dbrx-132b]
"""
import argparse
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.launch.mesh import make_smoke_mesh
from repro.models import transformer as T
from repro.serving.engine import make_prefill_step, make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dbrx-132b",
                    help="any assigned arch id (reduced smoke variant used)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = configs.smoke_config(args.arch)
    assert cfg.has_decode, f"{args.arch} is encoder-only"
    mesh = make_smoke_mesh((1, 1))
    rng = jax.random.PRNGKey(0)
    params = T.init_model(rng, cfg)
    cache_len = args.prompt_len + args.gen

    prefill = jax.jit(make_prefill_step(cfg, mesh, cache_len=cache_len))
    step = jax.jit(make_serve_step(cfg, mesh))

    # a batch of "requests" (synthetic prompts of equal length; ragged
    # batching would left-pad and mask — same cache machinery)
    prompts = jax.random.randint(rng, (args.batch, args.prompt_len),
                                 0, cfg.vocab_size)
    t0 = time.time()
    logits, caches = prefill(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    toks = []
    t0 = time.time()
    tok = jnp.argmax(logits[:, -1], -1)[:, None]
    for _ in range(args.gen):
        toks.append(tok)
        logits, caches = step(params, tok, caches)
        tok = jnp.argmax(logits[:, -1], -1)[:, None]
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    out = jnp.concatenate(toks, axis=1)
    print(f"arch={cfg.name}  batch={args.batch}")
    print(f"prefill {args.prompt_len} toks: {t_prefill*1e3:.1f} ms")
    print(f"decode  {args.gen} steps: {t_decode*1e3:.1f} ms "
          f"({args.batch*args.gen/t_decode:.1f} tok/s batched)")
    print("continuations[0]:", out[0].tolist())


if __name__ == "__main__":
    main()
