"""Quickstart: the HetuMoE layer in 60 lines.

Builds the paper's 16-expert MoE layer, routes a batch of tokens through
every stage of Algorithm 1 (gate → layout transform → AllToAll → experts
→ reverse transform), on an 8-device expert-parallel mesh (fake CPU
devices), with both flat and hierarchical AllToAll.

  PYTHONPATH=src python examples/quickstart.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import moe
from repro.core.config import MoEConfig
from repro.launch.mesh import make_smoke_mesh


def main():
    mesh = make_smoke_mesh((1, 8))              # 8-way expert parallelism
    d_model, d_ff, E = 256, 512, 16
    rng = jax.random.PRNGKey(0)

    x = jax.random.normal(rng, (4, 128, d_model), jnp.float32)  # (B, S, d)

    for a2a in ("flat", "hierarchical"):
        cfg = MoEConfig(num_experts=E, gate="switch", capacity_factor=1.25,
                        a2a=a2a, a2a_inner=4)
        params = moe.init_moe_params(rng, cfg, d_model, d_ff, E,
                                     act="swiglu", dtype=jnp.float32)
        apply_fn = jax.jit(lambda p, v: moe.sharded_moe_apply(
            mesh, cfg, p, v, num_experts=E, act="swiglu"))
        y, aux_loss, metrics = apply_fn(params, x)
        print(f"a2a={a2a:13s} out={y.shape} aux={float(aux_loss):.4f} "
              f"max_load={float(metrics['expert_load_max']):.3f}")
        if a2a == "flat":
            y_flat = y
        else:
            np.testing.assert_allclose(np.asarray(y_flat), np.asarray(y),
                                       rtol=1e-5, atol=1e-6)
            print("flat == hierarchical ✓ (the paper's optimization is "
                  "semantics-preserving; the win is in message aggregation)")


if __name__ == "__main__":
    main()
